//! Differential property suite for the pluggable aggregation subsystem
//! (`agg/`; seeded runner in `util::prop` — offline build, no proptest
//! crate, see docs/testing.md).
//!
//! Invariants:
//! * `Mean` behind the trait is `aggregate_weighted`, **bit-for-bit**;
//!   every degenerate policy (`Buffered{k=0, β=0}`, `TrimmedMean{0}`,
//!   `NormClip{∞}`) reproduces it bitwise too — the algebraic half of
//!   the refactor's equivalence gate.
//! * The trimmed mean obeys its breakdown bound: with at least as many
//!   values trimmed per tail as there are corrupted contributions, the
//!   output stays inside the honest values' envelope per coordinate —
//!   including against a seeded sign-flip from the corruption scenario.
//! * The coordinate median is bitwise permutation-invariant; the trimmed
//!   mean is permutation-invariant up to f64 summation order.
//! * `Buffered` holds updates until its threshold, flushes exactly what
//!   it holds, and replays bit-for-bit; `AdaptiveQuorum` stays within
//!   `[floor, 1]` and moves in the discard rate's direction.
//! * With a runtime (`make artifacts`): the degenerate `Buffered` engine
//!   run equals the synchronous `Mean` engine bit-for-bit (all
//!   `RoundRecord` fields via `to_bits` + CSV); momentum runs replay
//!   from their seed; and the trimmed-mean engine survives the sign-flip
//!   corruption scenario with real rejection accounting.
//!
//! Knobs: `PROPTEST_CASES` scales case counts, `PROPTEST_SEED` replays.

use std::sync::Arc;

use fedcore::agg::{
    aggregate_weighted, AdaptiveQuorum, AggPolicy, Aggregator, Buffered, CoordinateMedian, Mean,
    NormClip, TrimmedMean,
};
use fedcore::data::{self, Benchmark};
use fedcore::fl::{Engine, RunConfig, Strategy};
use fedcore::scenario::{CorruptionKind, CorruptionSpec};
use fedcore::util::prop::{check, env_cases, env_seed};
use fedcore::util::rng::Rng;

fn gen_locals(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| (0..dim).map(|_| rng.normal() as f32).collect()).collect()
}

fn refs(v: &[Vec<f32>]) -> Vec<&[f32]> {
    v.iter().map(|x| x.as_slice()).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: dim {i}: {x} vs {y}");
    }
}

// ---------- degenerate policies are the mean, bitwise ----------

#[test]
fn proptest_agg_degenerate_policies_are_bitwise_mean() {
    check("agg-degenerate-bitwise", env_seed(0xA66B), env_cases(100), |rng, _| {
        let n = 1 + rng.below(10);
        let dim = 1 + rng.below(48);
        let locals = gen_locals(rng, n, dim);
        let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 1.0)).collect();
        let current: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let want = aggregate_weighted(&refs(&locals), &weights).unwrap();

        let (mean, _) = Mean.aggregate_round(&current, &refs(&locals), &weights);
        assert_bits_eq(&want, &mean.unwrap(), "Mean via trait");

        let (buf, stats) =
            Buffered::new(0, 0.0).aggregate_round(&current, &refs(&locals), &weights);
        assert_bits_eq(&want, &buf.unwrap(), "Buffered{k=0, β=0}");
        assert_eq!(stats.buffered, 0);

        let (trim, stats) =
            TrimmedMean::new(0.0).aggregate_round(&current, &refs(&locals), &weights);
        assert_bits_eq(&want, &trim.unwrap(), "TrimmedMean{0}");
        assert_eq!(stats.rejected, 0);

        let (clip, stats) = NormClip::new(f64::INFINITY, Mean)
            .aggregate_round(&current, &refs(&locals), &weights);
        assert_bits_eq(&want, &clip.unwrap(), "NormClip{∞}");
        assert_eq!(stats.clipped, 0);
    });
}

// ---------- trimmed-mean breakdown bound ----------

#[test]
fn proptest_agg_trimmed_mean_breakdown_bound() {
    check("agg-trim-breakdown", env_seed(0x7B1B), env_cases(150), |rng, _| {
        let honest = 3 + rng.below(8);
        let bad = 1 + rng.below(2); // corrupted contributions
        let dim = 1 + rng.below(16);
        // Honest values in a known envelope; corrupted values arbitrary
        // and huge in either direction.
        let mut locals: Vec<Vec<f32>> = (0..honest)
            .map(|_| (0..dim).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect())
            .collect();
        for _ in 0..bad {
            let sign = if rng.below(2) == 0 { 1.0 } else { -1.0 };
            locals.push(
                (0..dim)
                    .map(|_| (sign * rng.range_f64(100.0, 1e6)) as f32)
                    .collect(),
            );
        }
        let n = locals.len();
        let weights = vec![1.0; n];
        // Trim at least `bad` from each tail (but keep 2g < n).
        let g = bad.min((n - 1) / 2);
        let trim_frac = (g as f64 + 0.5) / n as f64;
        let mut tm = TrimmedMean::new(trim_frac.min(0.49));
        assert!(tm.trim_count(n) >= g.min((n - 1) / 2), "generator bug: trim too small");
        let (out, stats) = tm.aggregate_round(&vec![0.0; dim], &refs(&locals), &weights);
        let out = out.unwrap();
        assert_eq!(stats.rejected, 2 * tm.trim_count(n));
        for (j, &v) in out.iter().enumerate() {
            let lo = (0..honest).map(|i| locals[i][j]).fold(f32::INFINITY, f32::min);
            let hi = (0..honest).map(|i| locals[i][j]).fold(f32::NEG_INFINITY, f32::max);
            assert!(
                v >= lo - 1e-4 && v <= hi + 1e-4,
                "coordinate {j}: trimmed mean {v} escaped honest envelope [{lo}, {hi}]"
            );
        }
    });
}

/// The acceptance gate: a seeded sign-flipped client (driven through the
/// actual scenario machinery, `CorruptionSpec::apply`) is provably
/// discarded by the trimmed mean — the robust aggregate stays inside the
/// honest envelope while the plain mean is dragged out of it.
#[test]
fn proptest_agg_trimmed_mean_discards_signflip_corruption() {
    check("agg-trim-vs-signflip", env_seed(0x51F1), env_cases(100), |rng, case| {
        let n = 4 + rng.below(6);
        let dim = 1 + rng.below(12);
        let global: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        // Honest clients: small positive steps from the global (updates
        // in (0.1, 1.0) per coordinate — a strictly positive envelope).
        let locals: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                global
                    .iter()
                    .map(|&g| (g as f64 + rng.range_f64(0.1, 1.0)) as f32)
                    .collect()
            })
            .collect();
        // One corrupted client: the scenario sign-flip reflects its
        // update to a strictly negative step — outside the envelope.
        let spec = CorruptionSpec {
            kind: CorruptionKind::SignFlip { scale: 1.0 + rng.range_f64(0.0, 3.0) },
            fraction: 1.0,
            seed: rng.next_u64(),
        };
        let mut corrupted = locals.clone();
        let victim = rng.below(n);
        spec.apply(&mut corrupted[victim], &global, case, victim);

        let all = refs(&corrupted);
        let weights = vec![1.0; n];
        // trim_frac a hair above 1/n so ⌊trim_frac·n⌋ = 1 survives f64
        // rounding: exactly the flipped value goes from the low tail.
        let (robust, stats) =
            TrimmedMean::new(1.2 / n as f64).aggregate_round(&global, &all, &weights);
        let robust = robust.unwrap();
        assert!(stats.rejected >= 2, "trim must reject the flipped value per coordinate");
        let (mean, _) = Mean.aggregate_round(&global, &all, &weights);
        let mean = mean.unwrap();
        for j in 0..dim {
            let lo = (0..n)
                .filter(|&i| i != victim)
                .map(|i| corrupted[i][j])
                .fold(f32::INFINITY, f32::min);
            let hi = (0..n)
                .filter(|&i| i != victim)
                .map(|i| corrupted[i][j])
                .fold(f32::NEG_INFINITY, f32::max);
            // The flipped update landed strictly outside the honest
            // envelope (a strictly negative step vs strictly positive
            // honest steps)…
            assert!(
                corrupted[victim][j] < lo,
                "coordinate {j}: generator bug — the flip stayed inside the envelope"
            );
            // …and the trimmed mean provably discards it: the robust
            // aggregate stays inside the honest envelope.
            assert!(
                robust[j] >= lo - 1e-4 && robust[j] <= hi + 1e-4,
                "coordinate {j}: trimmed mean {} did not discard the sign-flip",
                robust[j]
            );
        }
        // The plain mean, by contrast, gives the outlier full weight —
        // it cannot coincide with the robust aggregate.
        assert_ne!(mean, robust, "plain mean unexpectedly matched the trimmed mean");
    });
}

// ---------- permutation invariance ----------

#[test]
fn proptest_agg_median_is_bitwise_permutation_invariant() {
    check("agg-median-perm", env_seed(0x3ED1), env_cases(100), |rng, _| {
        let n = 1 + rng.below(9);
        let dim = 1 + rng.below(24);
        let locals = gen_locals(rng, n, dim);
        let (a, _) =
            CoordinateMedian.aggregate_round(&vec![0.0; dim], &refs(&locals), &vec![1.0; n]);
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let shuffled: Vec<Vec<f32>> = perm.iter().map(|&i| locals[i].clone()).collect();
        let (b, _) =
            CoordinateMedian.aggregate_round(&vec![0.0; dim], &refs(&shuffled), &vec![1.0; n]);
        assert_bits_eq(&a.unwrap(), &b.unwrap(), "median permutation");
    });
}

#[test]
fn proptest_agg_trimmed_mean_is_permutation_invariant_up_to_rounding() {
    check("agg-trim-perm", env_seed(0x7E21), env_cases(100), |rng, _| {
        let n = 3 + rng.below(8);
        let dim = 1 + rng.below(24);
        let locals = gen_locals(rng, n, dim);
        let weights = vec![1.0; n];
        let mut tm = TrimmedMean::new(rng.range_f64(0.05, 0.4));
        let (a, _) = tm.aggregate_round(&vec![0.0; dim], &refs(&locals), &weights);
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let shuffled: Vec<Vec<f32>> = perm.iter().map(|&i| locals[i].clone()).collect();
        let (b, _) = tm.aggregate_round(&vec![0.0; dim], &refs(&shuffled), &weights);
        for (x, y) in a.unwrap().iter().zip(&b.unwrap()) {
            assert!(
                (x - y).abs() <= 1e-5 * (1.0 + x.abs()),
                "trimmed mean not permutation-invariant: {x} vs {y}"
            );
        }
    });
}

// ---------- buffered protocol ----------

#[test]
fn proptest_agg_buffered_holds_flushes_and_replays() {
    check("agg-buffered-protocol", env_seed(0xB0FF), env_cases(100), |rng, _| {
        let dim = 1 + rng.below(16);
        let k = 2 + rng.below(6);
        let momentum = [0.0, 0.5][rng.below(2)];
        let rounds = 2 + rng.below(6);
        let per_round: Vec<Vec<Vec<f32>>> =
            (0..rounds).map(|_| gen_locals(rng, 1 + rng.below(3), dim)).collect();

        let drive = |k: usize| {
            let mut buf = Buffered::new(k, momentum);
            let mut params: Vec<f32> = vec![0.0; dim];
            let mut applied = 0usize;
            let mut held = 0usize;
            for contributions in &per_round {
                let w = vec![1.0; contributions.len()];
                let (out, stats) = buf.aggregate_round(&params, &refs(contributions), &w);
                held += contributions.len();
                if let Some(p) = out {
                    assert!(held >= k.max(1), "buffer applied below its threshold");
                    params = p;
                    applied += held;
                    held = 0;
                } else {
                    assert_eq!(stats.buffered, held, "buffered count out of sync");
                }
            }
            if let Some(p) = buf.flush(&params) {
                params = p;
                applied += held;
                held = 0;
            }
            assert_eq!(held, 0, "flush must drain the buffer");
            (params, applied)
        };

        let (a, applied_a) = drive(k);
        let (b, applied_b) = drive(k);
        assert_bits_eq(&a, &b, "buffered replay");
        assert_eq!(applied_a, applied_b);
        let total: usize = per_round.iter().map(|c| c.len()).sum();
        assert_eq!(applied_a, total, "every buffered update must apply exactly once");
    });
}

// ---------- adaptive quorum ----------

#[test]
fn proptest_agg_adaptive_quorum_bounded_and_directional() {
    check("agg-adaptive-quorum", env_seed(0xADA7), env_cases(150), |rng, _| {
        let floor = rng.range_f64(0.1, 0.9);
        let mut a = AdaptiveQuorum::new(floor);
        for _ in 0..rng.below(40) {
            let before = a.quorum();
            let folded = rng.below(5);
            let discarded = rng.below(5);
            a.observe(folded, discarded);
            let q = a.quorum();
            assert!(q >= floor - 1e-12 && q <= 1.0, "quorum {q} left [floor {floor}, 1]");
            let resolved = folded + discarded;
            if resolved > 0 && (discarded as f64 / resolved as f64) > 0.1 {
                assert!(q >= before, "discard-heavy round must not relax the quorum");
            } else {
                assert!(q <= before, "clean round must not tighten the quorum");
            }
        }
    });
}

// ---------- corruption scenario determinism ----------

#[test]
fn proptest_agg_corruption_membership_and_noise_replay() {
    check("agg-corruption-replay", env_seed(0xC0DE), env_cases(100), |rng, case| {
        let n = 1 + rng.below(40);
        let frac = rng.range_f64(0.0, 1.0);
        let spec = CorruptionSpec {
            kind: CorruptionKind::Noise { sigma: rng.range_f64(0.1, 2.0) },
            fraction: frac,
            seed: rng.next_u64(),
        };
        let a = spec.corrupted_clients(n);
        assert_eq!(a, spec.corrupted_clients(n), "membership must replay");
        // Membership is stable under fleet growth.
        let grown = spec.corrupted_clients(n + 5);
        assert_eq!(&grown[..n], &a[..]);
        // Noise replays per (round, client) and perturbs.
        let dim = 1 + rng.below(16);
        let global = vec![0.0f32; dim];
        let base: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let mut x = base.clone();
        let mut y = base.clone();
        spec.apply(&mut x, &global, case, 3);
        spec.apply(&mut y, &global, case, 3);
        assert_bits_eq(&x, &y, "noise replay");
        assert!(x.iter().zip(&base).any(|(p, q)| p != q), "noise must perturb");
    });
}

// ---------- engine differentials (runtime-backed) ----------

fn runtime_or_skip() -> Option<fedcore::runtime::Runtime> {
    fedcore::expt::try_runtime()
}

fn engine_cfg(rng: &mut Rng, case: usize) -> RunConfig {
    let strategies = [Strategy::FedAvg, Strategy::FedCore];
    RunConfig {
        strategy: strategies[case % strategies.len()],
        rounds: 2 + rng.below(2),
        epochs: 2 + rng.below(2),
        clients_per_round: 3 + rng.below(4),
        lr: 0.01,
        straggler_pct: 30.0,
        seed: rng.next_u64(),
        eval_every: 1,
        eval_cap: 128,
        ..RunConfig::default()
    }
}

fn assert_rounds_bitwise_equal(a: &fedcore::metrics::RunResult, b: &fedcore::metrics::RunResult) {
    assert_eq!(a.final_params, b.final_params, "final params diverged");
    assert_eq!(a.rounds.len(), b.rounds.len());
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {r} train_loss");
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "round {r} test_loss");
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "round {r} test_acc");
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "round {r} sim_time");
        assert_eq!(x.tail_time.to_bits(), y.tail_time.to_bits(), "round {r} tail_time");
        assert_eq!(x.client_times, y.client_times, "round {r} client_times");
        assert_eq!(x.dropped, y.dropped, "round {r} dropped");
        assert_eq!(x.agg_rejected, y.agg_rejected, "round {r} agg_rejected");
        assert_eq!(x.agg_clipped, y.agg_clipped, "round {r} agg_clipped");
        assert_eq!(x.coreset_clients, y.coreset_clients, "round {r} coreset_clients");
    }
    assert_eq!(a.to_csv(), b.to_csv(), "CSV serializations diverged");
}

/// The refactor gate: `Buffered{k=0, β=0}` through the engine equals the
/// `Mean` engine bit-for-bit (all round fields + CSV) — i.e. the
/// pre-refactor aggregation seam moved without moving a bit.
#[test]
fn proptest_agg_degenerate_buffered_equals_mean_engine() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("agg-engine-degenerate", env_seed(0xDEB0), env_cases(4), |rng, case| {
        let mean_cfg = engine_cfg(rng, case);
        let mut buf_cfg = mean_cfg.clone();
        buf_cfg.aggregator = AggPolicy::Buffered { k: 0, momentum: 0.0 };
        let mut trim_cfg = mean_cfg.clone();
        trim_cfg.aggregator = AggPolicy::TrimmedMean { trim_frac: 0.0 };

        let mean = Engine::new(&rt, &ds, mean_cfg).unwrap().run().unwrap();
        let buffered = Engine::new(&rt, &ds, buf_cfg).unwrap().run().unwrap();
        assert_rounds_bitwise_equal(&mean, &buffered);
        let trimmed = Engine::new(&rt, &ds, trim_cfg).unwrap().run().unwrap();
        assert_rounds_bitwise_equal(&mean, &trimmed);
    });
}

/// Momentum runs replay bit-for-bit from their seed (the buffered state
/// is a pure function of the contribution sequence).
#[test]
fn proptest_agg_momentum_run_replays_from_seed() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("agg-momentum-replay", env_seed(0x3E41), env_cases(3), |rng, case| {
        let mut cfg = engine_cfg(rng, case);
        cfg.aggregator =
            AggPolicy::Buffered { k: rng.below(3), momentum: rng.range_f64(0.1, 0.9) };
        let a = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();
        let b = Engine::new(&rt, &ds, cfg).unwrap().run().unwrap();
        assert_rounds_bitwise_equal(&a, &b);
    });
}

/// The corruption scenario bites through the engine, the robust policy
/// does real rejection work under it, and corrupted runs replay.
#[test]
fn proptest_agg_engine_signflip_scenario_exercises_robust_path() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("agg-engine-corruption", env_seed(0x5CAB), env_cases(3), |rng, case| {
        let clean_cfg = engine_cfg(rng, case);
        let spec = CorruptionSpec {
            kind: CorruptionKind::SignFlip { scale: 2.0 },
            fraction: 0.5,
            seed: 5,
        };
        let mut mean_cfg = clean_cfg.clone();
        mean_cfg.corruption = Some(spec);
        let mut robust_cfg = mean_cfg.clone();
        robust_cfg.aggregator = AggPolicy::TrimmedMean { trim_frac: 0.34 };

        let clean = Engine::new(&rt, &ds, clean_cfg).unwrap().run().unwrap();
        let corrupted = Engine::new(&rt, &ds, mean_cfg.clone()).unwrap().run().unwrap();
        assert_ne!(
            clean.final_params, corrupted.final_params,
            "sign-flip corruption must perturb the mean engine"
        );
        let robust = Engine::new(&rt, &ds, robust_cfg.clone()).unwrap().run().unwrap();
        let (rejected, _) = robust.agg_totals();
        assert!(rejected > 0, "trimmed mean did no rejection work under corruption");
        // Corrupted runs replay bit-for-bit (membership + noise streams
        // are pure functions of the spec).
        let again = Engine::new(&rt, &ds, robust_cfg).unwrap().run().unwrap();
        assert_rounds_bitwise_equal(&robust, &again);
    });
}
