//! Differential property suite for the async round-overlap pipeline
//! (seeded runner in `util::prop`; offline build, no proptest crate —
//! see docs/testing.md).
//!
//! Invariants:
//! * Staleness weights are 1 on time, strictly decreasing in staleness
//!   (for `alpha > 0`), and bounded in (0, 1].
//! * The in-flight ledger folds or discards every late update exactly
//!   once, never past the staleness cap, and drains in deterministic
//!   `(origin_round, slot)` order.
//! * `aggregate_weighted` with unit weights reproduces `aggregate`
//!   **bit-for-bit** — the algebraic half of the degenerate-equivalence
//!   contract.
//! * Quorum counts are monotone and bounded (`quorum = 1.0` ⇒ everyone).
//! * With a runtime (`make artifacts`): the degenerate overlap policy
//!   (`quorum = 1.0`, `max_staleness = 0`) reproduces the synchronous
//!   engine's `RunResult` bit-identically; overlapped runs replay
//!   bit-for-bit from their seed; every overlapped round's server-advance
//!   time is ≤ the synchronous round's; and overlapped sharded equals
//!   overlapped sequential.
//!
//! Knobs: `PROPTEST_CASES` scales case counts, `PROPTEST_SEED` replays.

use std::sync::Arc;

use fedcore::coreset::Method;
use fedcore::data::{self, Benchmark};
use fedcore::exec::{overlapped::staleness_weight, DelayedUpdate, InFlight, OverlapConfig};
use fedcore::fl::{aggregate, aggregate_weighted, CoresetMode, Engine, RunConfig, Strategy};
use fedcore::sim::clock::RoundTiming;
use fedcore::util::prop::{check, env_cases, env_seed};
use fedcore::util::rng::Rng;

// ---------- staleness weights (satellite c) ----------

#[test]
fn proptest_overlap_stale_weight_monotone_and_bounded() {
    check("overlap-weight-monotone", env_seed(0x57A1E), env_cases(200), |rng, _| {
        // alpha = 0 exactly (no discount) or bounded away from zero, so
        // the strict-decrease check never fights f64 rounding.
        let alpha = if rng.below(4) == 0 { 0.0 } else { rng.range_f64(0.1, 4.0) };
        let cfg = OverlapConfig { quorum: 0.5, max_staleness: 8, alpha };
        assert_eq!(cfg.weight(0), 1.0, "on-time updates must weigh exactly 1");
        let mut prev = cfg.weight(0);
        for s in 1..=12usize {
            let w = cfg.weight(s);
            assert!(w > 0.0 && w <= 1.0, "weight {w} out of (0, 1] at staleness {s}");
            if alpha > 0.0 {
                assert!(w < prev, "weight not strictly decreasing: {w} !< {prev} at s = {s}");
            } else {
                assert_eq!(w, 1.0, "alpha = 0 must not discount");
            }
            prev = w;
        }
        // The free function and the config method agree.
        let s = rng.below(10);
        assert_eq!(cfg.weight(s), staleness_weight(s, alpha));
    });
}

// ---------- in-flight ledger: discard-cap enforcement (satellite c) ----------

/// Drive the ledger exactly like the engine does — push late finishers
/// after each round's aggregation instant, drain arrivals at the next
/// instants, doom-filter, final drain — and check that every update folds
/// or discards exactly once, that nothing folds past the staleness cap,
/// and that arrivals drain in `(origin_round, slot)` order.
#[test]
fn proptest_overlap_in_flight_folds_or_discards_exactly_once() {
    check("overlap-inflight-protocol", env_seed(0x0F117), env_cases(150), |rng, _| {
        let rounds = 3 + rng.below(8);
        let cap = rng.below(4);
        let mut ledger = InFlight::new();
        let (mut pushed, mut folded, mut discarded) = (0usize, 0usize, 0usize);
        let mut now = 0.0f64;
        for r in 0..rounds {
            let agg_instant = now + rng.range_f64(0.5, 2.0);
            for slot in 0..rng.below(4) {
                // A late finisher arrives strictly after its own round's
                // aggregation instant (the engine's on-time cut).
                ledger.push(DelayedUpdate {
                    origin_round: r,
                    slot,
                    client: slot,
                    arrival: agg_instant + rng.range_f64(0.0, 5.0) + 1e-9,
                    params: vec![r as f32],
                });
                pushed += 1;
            }
            let arrived = ledger.take_arrived(agg_instant);
            let mut prev_key: Option<(usize, usize)> = None;
            for u in &arrived {
                let key = (u.origin_round, u.slot);
                if let Some(p) = prev_key {
                    assert!(p < key, "arrivals out of (origin, slot) order: {p:?} then {key:?}");
                }
                prev_key = Some(key);
                assert!(u.origin_round < r, "an update arrived within its own round");
                let staleness = r - u.origin_round;
                // The doomed filter ran last round, so nothing that
                // arrives can exceed the cap.
                assert!(staleness <= cap, "staleness {staleness} folded past cap {cap}");
                folded += 1;
            }
            discarded += ledger.discard_doomed(r, cap);
            now = agg_instant;
        }
        discarded += ledger.discard_all();
        assert_eq!(
            pushed,
            folded + discarded,
            "every late update must fold or discard exactly once"
        );
        assert!(ledger.is_empty());
    });
}

// ---------- weighted aggregation degenerates bitwise ----------

#[test]
fn proptest_overlap_unit_weight_aggregation_is_bitwise_plain() {
    check("overlap-agg-degenerate", env_seed(0xA66D), env_cases(100), |rng, _| {
        let k = 1 + rng.below(10);
        let dim = 1 + rng.below(64);
        let locals: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = locals.iter().map(|v| v.as_slice()).collect();
        let plain = aggregate(&refs).unwrap();
        let weighted = aggregate_weighted(&refs, &vec![1.0; k]).unwrap();
        for (i, (x, y)) in plain.iter().zip(&weighted).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "unit-weight aggregation diverged from plain at dim {i}: {x} vs {y}"
            );
        }
    });
}

// ---------- quorum arithmetic ----------

#[test]
fn proptest_overlap_quorum_count_monotone_and_bounded() {
    check("overlap-quorum-count", env_seed(0x900A), env_cases(200), |rng, _| {
        let n = rng.below(40);
        let q1 = rng.range_f64(0.01, 1.0);
        let q2 = rng.range_f64(0.01, 1.0);
        let (lo, hi) = if q1 <= q2 { (q1, q2) } else { (q2, q1) };
        let lo_cfg = OverlapConfig { quorum: lo, ..OverlapConfig::default() };
        let hi_cfg = OverlapConfig { quorum: hi, ..OverlapConfig::default() };
        let (a, b) = (lo_cfg.quorum_count(n), hi_cfg.quorum_count(n));
        assert!(a <= b, "quorum count not monotone: {a} > {b} for {lo} <= {hi}");
        if n > 0 {
            assert!((1..=n).contains(&a), "count {a} out of [1, {n}]");
            assert_eq!(
                OverlapConfig::degenerate().quorum_count(n),
                n,
                "full quorum must wait for everyone"
            );
        } else {
            assert_eq!(a, 0);
        }
    });
}

#[test]
fn proptest_overlap_round_timing_quorum_below_tail() {
    check("overlap-timing", env_seed(0x71A11), env_cases(100), |rng, _| {
        let n = 1 + rng.below(12);
        let times: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 20.0)).collect();
        let mut sorted = times.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = 1 + rng.below(n);
        let t = RoundTiming::with_quorum(times.clone(), sorted[q - 1]);
        assert_eq!(t.round_time, sorted[q - 1]);
        assert_eq!(t.tail_time, *sorted.last().unwrap());
        assert!(t.round_time <= t.tail_time, "quorum time past the straggler tail");
        // Full quorum is the synchronous record.
        let full = RoundTiming::with_quorum(times.clone(), *sorted.last().unwrap());
        let sync = RoundTiming::from_clients(times);
        assert_eq!(full.round_time.to_bits(), sync.round_time.to_bits());
        assert_eq!(full.tail_time.to_bits(), sync.tail_time.to_bits());
    });
}

// ---------- engine differentials (runtime-backed) ----------

fn runtime_or_skip() -> Option<fedcore::runtime::Runtime> {
    fedcore::expt::try_runtime()
}

fn base_cfg(rng: &mut Rng, case: usize) -> RunConfig {
    let strategies = [
        Strategy::FedAvg,
        Strategy::FedCore,
        Strategy::FedAvgDS,
        Strategy::FedProx { mu: 0.1 },
    ];
    RunConfig {
        strategy: strategies[case % strategies.len()],
        rounds: 2 + rng.below(2),
        epochs: 2 + rng.below(2),
        clients_per_round: 3 + rng.below(4),
        lr: 0.01,
        straggler_pct: [10.0, 30.0][rng.below(2)],
        seed: rng.next_u64(),
        coreset_method: Method::FasterPam,
        coreset_mode: [CoresetMode::Adaptive, CoresetMode::Static][rng.below(2)],
        eval_every: 1,
        eval_cap: 128,
        workers: 1,
        trace: None,
        overlap: None,
        verbose: false,
        ..RunConfig::default()
    }
}

fn random_overlap(rng: &mut Rng) -> OverlapConfig {
    OverlapConfig {
        quorum: rng.range_f64(0.25, 1.0),
        max_staleness: rng.below(4),
        alpha: rng.range_f64(0.0, 3.0),
    }
}

fn assert_rounds_bitwise_equal(a: &fedcore::metrics::RunResult, b: &fedcore::metrics::RunResult) {
    assert_eq!(a.final_params, b.final_params, "final params diverged");
    assert_eq!(a.rounds.len(), b.rounds.len());
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {r} train_loss");
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "round {r} test_loss");
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "round {r} test_acc");
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "round {r} sim_time");
        assert_eq!(x.tail_time.to_bits(), y.tail_time.to_bits(), "round {r} tail_time");
        assert_eq!(x.client_times, y.client_times, "round {r} client_times");
        assert_eq!(x.dropped, y.dropped, "round {r} dropped");
        assert_eq!(x.stale_folded, y.stale_folded, "round {r} stale_folded");
        assert_eq!(x.stale_discarded, y.stale_discarded, "round {r} stale_discarded");
        assert_eq!(x.stale_weight.to_bits(), y.stale_weight.to_bits(), "round {r} stale_weight");
        assert_eq!(x.coreset_clients, y.coreset_clients, "round {r} coreset_clients");
    }
    assert_eq!(a.to_csv(), b.to_csv(), "CSV serializations diverged");
}

/// Satellite (a): quorum = 1.0 + max_staleness = 0 must be the
/// synchronous engine, bit-for-bit, for every strategy/config.
#[test]
fn proptest_overlap_degenerate_equals_sequential() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("overlap-degenerate-equivalence", env_seed(0xDE6E), env_cases(4), |rng, case| {
        let sync_cfg = base_cfg(rng, case);
        let mut over_cfg = sync_cfg.clone();
        over_cfg.overlap = Some(OverlapConfig::degenerate());

        let sync = Engine::new(&rt, &ds, sync_cfg).unwrap().run().unwrap();
        let over = Engine::new(&rt, &ds, over_cfg).unwrap().run().unwrap();
        assert_rounds_bitwise_equal(&sync, &over);
        let (folded, discarded) = over.stale_totals();
        assert_eq!((folded, discarded), (0, 0), "degenerate run used the stale path");
    });
}

/// Satellite (b): an overlapped run replays bit-for-bit from its seed
/// (honoring PROPTEST_SEED like every other suite).
#[test]
fn proptest_overlap_replay_is_deterministic() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("overlap-seed-replay", env_seed(0x8EB1A), env_cases(4), |rng, case| {
        let mut cfg = base_cfg(rng, case);
        cfg.overlap = Some(random_overlap(rng));
        let a = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();
        let b = Engine::new(&rt, &ds, cfg).unwrap().run().unwrap();
        assert_rounds_bitwise_equal(&a, &b);
    });
}

/// Satellite (d): the overlapped server never takes longer than the
/// synchronous barrier — per round and in total. Traceless configs keep
/// selection (and hence per-round client times) identical between the two
/// modes, so the comparison is exact.
#[test]
fn proptest_overlap_round_times_never_exceed_synchronous() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("overlap-round-time-bound", env_seed(0x1E55), env_cases(4), |rng, case| {
        let sync_cfg = base_cfg(rng, case);
        let mut over_cfg = sync_cfg.clone();
        over_cfg.overlap = Some(random_overlap(rng));

        let sync = Engine::new(&rt, &ds, sync_cfg).unwrap().run().unwrap();
        let over = Engine::new(&rt, &ds, over_cfg).unwrap().run().unwrap();
        assert_eq!(sync.rounds.len(), over.rounds.len());
        for (s, o) in sync.rounds.iter().zip(&over.rounds) {
            let r = s.round;
            // Same cohort ⇒ identical straggler tails; the server advance
            // is capped by the synchronous barrier.
            assert_eq!(s.client_times, o.client_times, "round {r} cohorts diverged");
            assert_eq!(s.tail_time.to_bits(), o.tail_time.to_bits(), "round {r} tail");
            assert!(
                o.sim_time <= s.sim_time,
                "round {r}: overlapped advance {} exceeds synchronous {}",
                o.sim_time,
                s.sim_time
            );
        }
        assert!(
            over.total_sim_time() <= sync.total_sim_time(),
            "overlapped total {} exceeds synchronous {}",
            over.total_sim_time(),
            sync.total_sim_time()
        );
    });
}

/// The executor determinism contract survives overlap: a sharded pool
/// under the overlapped pipeline matches the sequential overlapped run
/// bit-for-bit.
#[test]
fn proptest_overlap_sharded_matches_sequential() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("overlap-exec-equivalence", env_seed(0x5A4D), env_cases(4), |rng, case| {
        let mut cfg = base_cfg(rng, case);
        cfg.overlap = Some(random_overlap(rng));
        let seq = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();
        cfg.workers = 2 + rng.below(3);
        let par = Engine::new(&rt, &ds, cfg).unwrap().run().unwrap();
        assert_rounds_bitwise_equal(&seq, &par);
    });
}
