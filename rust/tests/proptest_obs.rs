//! Differential property suite for the observability subsystem
//! (`obs/`; seeded runner in `util::prop` — offline build, no proptest
//! crate, see docs/testing.md).
//!
//! Invariants:
//! * Observability is **write-only** (determinism rule 7): a
//!   `Jsonl`-traced run reproduces the `Null`-recorder run bit-for-bit —
//!   final model bytes, every round record, the model CSV, the dispatch
//!   ledger CSV, and checkpoint files — across strategies, both dispatch
//!   policies, and the overlap pipeline.
//! * Every traced run's JSONL passes the schema + span-nesting checks in
//!   `obs::report`, and renders one phase-table row per round.
//! * Seeded trace replay: two traced runs of the same config produce the
//!   identical record sequence modulo the wall-clock fields (span
//!   `wall_*_ns` bounds and `mem` samples are scrubbed; everything else —
//!   virtual times, counters, events, job/worker spans — must match).
//! * Synthetic traces round-trip the writer → loader → checker path, and
//!   the checker rejects tampered files (version bumps, missing header,
//!   non-JSON lines) — no runtime needed.
//! * Quantile sketches merge worker-count- and fold-order-invariantly:
//!   any sharding of a value stream, merged in any order, reproduces the
//!   sequential sketch bit-for-bit (serialized JSON equality).
//!
//! Knobs: `PROPTEST_CASES` scales case counts, `PROPTEST_SEED` replays.

use std::sync::Arc;

use fedcore::agg::{AggPolicy, TreeSpec};
use fedcore::coreset::Method;
use fedcore::data::{self, Benchmark};
use fedcore::exec::{DispatchPolicy, OverlapConfig};
use fedcore::fl::{Checkpoint, CoresetMode, Engine, RunConfig, Strategy};
use fedcore::metrics::RunResult;
use fedcore::obs::health::HealthConfig;
use fedcore::obs::report::Trace;
use fedcore::obs::sketch::Sketch;
use fedcore::obs::{Counter, Jsonl, Null, ObsConfig, Phase, Record, Recorder};
use fedcore::runtime::Runtime;
use fedcore::scenario::{ChurnModel, TraceSpec};
use fedcore::util::json::{write_json, Json};
use fedcore::util::prop::{check, env_cases, env_seed};
use fedcore::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    fedcore::expt::try_runtime()
}

/// Unique scratch path (tests run concurrently in one process, so the
/// pid alone cannot disambiguate).
fn scratch(tag: &str) -> std::path::PathBuf {
    static SCRATCH: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    let nonce = SCRATCH.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!("fedcore-obs-{}-{tag}-{nonce}.jsonl", std::process::id()))
}

// ---------- writer → loader → checker round-trip, no runtime ----------

/// Emit a well-formed multi-run trace through the real `Jsonl` writer:
/// random round counts, lifecycle spans partitioning each round's wall
/// window, the full counter registry, occasional mem records.
fn write_demo_trace(rec: &Jsonl, rng: &mut Rng) -> (usize, usize) {
    let runs = 1 + rng.below(2);
    let rounds = 1 + rng.below(3);
    for _ in 0..runs {
        rec.record(&Record::Event {
            name: "run_start",
            round: 0,
            fields: vec![("rounds", Json::Num(rounds as f64))],
        });
        let mut w = 10u64;
        for r in 0..rounds {
            let cuts: Vec<u64> = (0..5).map(|_| 1 + rng.below(100) as u64).collect();
            let total: u64 = cuts.iter().sum();
            let t = r as f64;
            rec.record(&Record::span(Phase::Round, r, (w, w + total), (t, t + 1.0)));
            let mut edge = w;
            for (phase, cut) in Phase::LIFECYCLE.into_iter().zip(&cuts) {
                rec.record(&Record::span(phase, r, (edge, edge + cut), (t, t + 1.0)));
                edge += cut;
            }
            for counter in Counter::ALL {
                let value = rng.below(10) as u64;
                rec.record(&Record::CounterVal { counter, round: r, value });
            }
            if rng.below(2) == 0 {
                rec.record(&Record::Mem { round: r, rss_pages: 64, rss_bytes: 64 * 4096 });
            }
            w += total + rng.below(50) as u64;
        }
    }
    (runs, rounds)
}

#[test]
fn proptest_obs_jsonl_round_trips_and_checker_rejects_tampering() {
    check("obs-jsonl-roundtrip", env_seed(0x0B51), env_cases(40), |rng, case| {
        let path = scratch("roundtrip");
        let rec = Jsonl::create(&path, "engine", fedcore::util::bench::provenance(7, 2, 1.0))
            .expect("creating trace");
        let (runs, rounds) = write_demo_trace(&rec, rng);
        drop(rec);

        let trace = fedcore::obs::report::load(&path).expect("loading trace back");
        let n = trace.check().expect("well-formed trace must pass");
        // header + per-run (run_start + rounds × (6 spans + 10 counters [+ mem]))
        assert!(n >= 1 + runs * (1 + rounds * 16), "suspiciously few records: {n}");
        assert_eq!(trace.segments().len(), runs);
        // Every round renders a phase-table row with full wall coverage
        // (the lifecycle spans partition each round window exactly).
        let table = trace.phase_table();
        assert_eq!(table.lines().count(), 1 + rounds, "table:\n{table}");
        assert!(table.lines().skip(1).all(|l| l.trim_end().ends_with("100.0%")));
        let summary = trace.summary();
        assert!(summary.contains("counters:"), "summary:\n{summary}");
        let svg = trace.timeline_svg("roundtrip");
        assert!(svg.starts_with("<svg") && svg.ends_with("</svg>\n"));

        // Tamper with the file: the checker must refuse all of it.
        let text = std::fs::read_to_string(&path).expect("trace text");
        let _ = std::fs::remove_file(&path);
        match case % 3 {
            0 => {
                // Schema version bump on a record line.
                let tampered = text.replacen("\"v\":2,", "\"v\":99,", 2);
                let t = Trace::from_text(&tampered).expect("still line-valid JSON");
                assert!(t.check().is_err(), "version bump must fail the check");
            }
            1 => {
                // Drop the header line.
                let tampered: String =
                    text.lines().skip(1).map(|l| format!("{l}\n")).collect();
                let t = Trace::from_text(&tampered).expect("still line-valid JSON");
                assert!(t.check().is_err(), "headerless trace must fail the check");
            }
            _ => {
                // A non-JSON line fails at parse time, with its line number.
                let tampered = format!("{text}not json\n");
                let err = Trace::from_text(&tampered).expect_err("garbage line must not parse");
                assert!(format!("{err:#}").contains("line"), "error names no line: {err:#}");
            }
        }
    });
}

#[test]
fn proptest_obs_null_recorder_is_inert_and_configs_build() {
    check("obs-null-inert", env_seed(0x0B52), env_cases(20), |rng, _| {
        assert!(!Null.enabled());
        assert_eq!(Null.now_ns(), 0, "the untraced path never reads the clock");
        Null.record(&Record::span(Phase::Round, rng.below(100), (0, 1), (0.0, 1.0)));

        let off = ObsConfig::Off.build(7, 3).expect("Off always builds");
        assert!(!off.enabled());
        assert_eq!(ObsConfig::Off.path(), None);

        let path = scratch("build");
        let cfg = ObsConfig::Jsonl { path: path.display().to_string(), scale: 0.5, health: None };
        assert_eq!(cfg.path(), Some(path.display().to_string().as_str()));
        let rec = cfg.build(rng.next_u64(), 1 + rng.below(5)).expect("Jsonl builds");
        assert!(rec.enabled());
        drop(rec);
        // Building the sink already wrote the provenance header.
        let trace = fedcore::obs::report::load(&path).expect("header written at build");
        assert_eq!(trace.check().expect("header-only trace is valid"), 1);
        let _ = std::fs::remove_file(&path);
    });
}

// ---------- runtime-gated: the rule-7 differential harness ----------

fn agg_for(case: usize) -> (AggPolicy, Option<f64>) {
    let clip = if case % 2 == 0 { None } else { Some(2.5) };
    let policy = match (case / 2) % 4 {
        0 => AggPolicy::Mean,
        1 => AggPolicy::Buffered { k: 3, momentum: 0.2 },
        2 => AggPolicy::TrimmedMean { trim_frac: 0.1 },
        _ => AggPolicy::CoordinateMedian,
    };
    (policy, clip)
}

/// Random run configuration cycling all four strategies, both dispatch
/// policies, the aggregation policies, churn traces, and the overlap
/// pipeline — everything the trace instruments.
fn differential_cfg(rng: &mut Rng, case: usize) -> RunConfig {
    let strategies = [
        Strategy::FedCore,
        Strategy::FedAvgDS,
        Strategy::FedProx { mu: 0.1 },
        Strategy::FedAvg,
    ];
    let (aggregator, clip_norm) = agg_for(case);
    let trace = (rng.below(2) == 0).then(|| {
        TraceSpec::from_model(
            ChurnModel::Markov {
                mean_on: rng.range_f64(2.0, 8.0),
                mean_off: rng.range_f64(0.5, 3.0),
                p_init_online: 0.8,
            },
            24.0,
            rng.next_u64(),
        )
    });
    let overlap = (rng.below(2) == 0).then(|| OverlapConfig {
        quorum: rng.range_f64(0.4, 1.0),
        max_staleness: rng.below(3),
        alpha: 1.0,
    });
    // Hierarchical aggregation at a random fanout on half the cases: the
    // tree topology is config, never observable, so the traced≡untraced
    // gate must hold through it too. Buffered tiers may only run at the
    // root (edges rebuild every round).
    let agg_tree = (rng.below(2) == 0).then(|| {
        let fanout = 1 + rng.below(6);
        match aggregator {
            AggPolicy::Buffered { .. } => {
                TreeSpec { fanout, edge: AggPolicy::Mean, root: aggregator }
            }
            edge => TreeSpec { fanout, edge, root: AggPolicy::Mean },
        }
    });
    RunConfig {
        strategy: strategies[case % strategies.len()],
        rounds: 1 + rng.below(2),
        epochs: 2 + rng.below(2),
        clients_per_round: 3 + rng.below(4),
        lr: 0.01,
        straggler_pct: [10.0, 30.0][rng.below(2)],
        seed: rng.next_u64(),
        coreset_method: Method::FasterPam,
        coreset_mode: [CoresetMode::Adaptive, CoresetMode::Static][rng.below(2)],
        // Exercise the warm-start rounds too: the traced≡untraced gate
        // must hold when coresets are rebuilt only every few rounds.
        coreset_refresh: 1 + rng.below(3),
        eval_every: 1,
        eval_cap: 128,
        workers: 1 + rng.below(3),
        dispatch: [DispatchPolicy::RoundRobin, DispatchPolicy::WorkStealing][rng.below(2)],
        trace,
        overlap,
        aggregator,
        clip_norm,
        agg_tree,
        verbose: false,
        ..RunConfig::default()
    }
}

/// Serialized checkpoint bytes of a run's final model (written through
/// the real `Checkpoint` writer, then read back raw).
fn checkpoint_bytes(res: &RunResult, tag: &str) -> Vec<u8> {
    let path = scratch(&format!("ckpt-{tag}"));
    Checkpoint::new(res.benchmark.clone(), res.rounds.len() as u64, res.final_params.clone())
        .save(&path)
        .expect("writing checkpoint");
    let bytes = std::fs::read(&path).expect("reading checkpoint back");
    let _ = std::fs::remove_file(&path);
    bytes
}

/// Rule 7: tracing must not perturb a single output bit.
fn assert_model_outputs_bitwise_equal(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.final_params.len(), b.final_params.len(), "{what}: param count");
    for (i, (x, y)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: final param {i}: {x} vs {y}");
    }
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{what} round {r} loss");
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "{what} round {r} test_acc");
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{what} round {r} sim_time");
        assert_eq!(x.client_times, y.client_times, "{what} round {r} client_times");
        assert_eq!(x.stale_folded, y.stale_folded, "{what} round {r} stale_folded");
        assert_eq!(x.stale_discarded, y.stale_discarded, "{what} round {r} stale_discarded");
    }
    assert_eq!(a.to_csv(), b.to_csv(), "{what}: model CSV diverged");
    assert_eq!(a.to_dispatch_csv(), b.to_dispatch_csv(), "{what}: dispatch CSV diverged");
    assert_eq!(
        checkpoint_bytes(a, "a"),
        checkpoint_bytes(b, "b"),
        "{what}: checkpoint bytes diverged"
    );
}

/// The centerpiece: `Jsonl`-traced — with **health sampling on** —
/// ≡ `Null`-recorder **bit-for-bit** across strategies, both dispatch
/// policies, and overlap; the trace itself passes the schema + nesting
/// checks with one phase-table row per round and carries at least one
/// schema-v2 `snapshot` record.
#[test]
fn proptest_obs_traced_run_is_bitwise_identical_to_untraced() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("obs-rule7-differential", env_seed(0x0B53), env_cases(8), |rng, case| {
        let mut cfg = differential_cfg(rng, case);
        cfg.obs = ObsConfig::Off;
        let plain = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();

        let path = scratch("rule7");
        // Health sampling at a random ledger size and cadence: the
        // straggler forensics must stay on the write-only side of rule 7.
        cfg.obs = ObsConfig::Jsonl {
            path: path.display().to_string(),
            scale: 0.15,
            health: Some(HealthConfig {
                top_k: 1 + rng.below(8),
                snapshot_every: 1 + rng.below(3),
            }),
        };
        let traced = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();

        let what = format!(
            "{} agg={} workers={} dispatch={}",
            plain.strategy,
            cfg.aggregator.label(),
            cfg.workers,
            cfg.dispatch.label()
        );
        assert_model_outputs_bitwise_equal(&plain, &traced, &what);

        let trace = fedcore::obs::report::load(&path).expect("trace written");
        trace.check().unwrap_or_else(|e| panic!("{what}: trace failed the check: {e:#}"));
        let table = trace.phase_table();
        assert_eq!(table.lines().count(), 1 + cfg.rounds, "{what}: table:\n{table}");
        // The ledger always snapshots the final round, so a health-traced
        // run must carry at least one v2 snapshot — and the report layer
        // must render a leaderboard from it.
        let snapshots = trace
            .records
            .iter()
            .filter(|r| r.get("t").and_then(Json::as_str) == Some("snapshot"))
            .count();
        assert!(snapshots >= 1, "{what}: no snapshot records in a health-traced run");
        let health = trace.health_report();
        assert!(health.contains("straggler leaderboard"), "{what}: report:\n{health}");
        let _ = std::fs::remove_file(&path);
    });
}

/// Strip the nondeterministic wall-clock surface from a trace: span
/// `wall_*_ns` bounds go to zero and `mem` records drop; everything
/// else (order included) must replay from the seed.
fn scrub_wall(trace: &Trace) -> Vec<String> {
    trace
        .records
        .iter()
        .filter_map(|rec| {
            let mut rec = rec.clone();
            if let Json::Obj(map) = &mut rec {
                if map.get("t") == Some(&Json::Str("mem".into())) {
                    return None;
                }
                map.remove("wall_start_ns");
                map.remove("wall_end_ns");
            }
            let mut line = String::new();
            write_json(&rec, &mut line);
            Some(line)
        })
        .collect()
}

/// Seeded trace replay: the same config twice gives the identical record
/// sequence modulo wall-clock fields.
#[test]
fn proptest_obs_trace_replays_deterministically_modulo_wall_clock() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("obs-trace-replay", env_seed(0x0B54), env_cases(4), |rng, case| {
        let cfg = differential_cfg(rng, case);
        let one_run = |tag: &str| {
            let path = scratch(tag);
            let mut cfg = cfg.clone();
            // Snapshot records carry no wall-clock fields, so they must
            // replay verbatim along with everything else.
            cfg.obs = ObsConfig::Jsonl {
                path: path.display().to_string(),
                scale: 0.15,
                health: Some(HealthConfig::default()),
            };
            Engine::new(&rt, &ds, cfg).unwrap().run().unwrap();
            let trace = fedcore::obs::report::load(&path).expect("trace written");
            let _ = std::fs::remove_file(&path);
            trace
        };
        let a = one_run("replay-a");
        let b = one_run("replay-b");
        let (sa, sb) = (scrub_wall(&a), scrub_wall(&b));
        assert_eq!(sa.len(), sb.len(), "record counts diverged");
        for (i, (x, y)) in sa.iter().zip(&sb).enumerate() {
            assert_eq!(x, y, "trace record {i} did not replay");
        }
    });
}

// ---------- sketch merge invariance, no runtime ----------

/// Serialize a sketch to its canonical JSON line — bitwise comparison
/// surface for the merge properties (covers counts, count, min, max).
fn sketch_line(s: &Sketch) -> String {
    let mut line = String::new();
    write_json(&s.to_json(), &mut line);
    line
}

/// Worker-count and fold-order invariance: any partition of a value
/// stream into shards, with the shard sketches merged in any order,
/// reproduces the sequential single-sketch result bit-for-bit. This is
/// what lets the health ledger aggregate identically no matter how the
/// executor schedules clients onto workers.
#[test]
fn proptest_obs_sketch_merge_is_shard_and_order_invariant() {
    check("obs-sketch-merge", env_seed(0x0B55), env_cases(60), |rng, _| {
        let n = 1 + rng.below(400);
        let values: Vec<f64> = (0..n)
            .map(|_| {
                match rng.below(10) {
                    // Heavy tail: decades of scale, like straggler times.
                    0 => rng.range_f64(1e-9, 1e-3),
                    1 => rng.range_f64(1e3, 1e12),
                    // Pathological inputs the sketch must absorb quietly.
                    2 => [0.0, -1.0, f64::NAN, f64::INFINITY][rng.below(4)],
                    _ => rng.range_f64(1e-3, 1e3),
                }
            })
            .collect();

        let mut sequential = Sketch::new();
        for &v in &values {
            sequential.insert(v);
        }

        // Random shard assignment at a random worker count, merged in a
        // random order (shuffle), folded both left-to-right and reversed.
        let workers = 1 + rng.below(8);
        let mut shards = vec![Sketch::new(); workers];
        for &v in &values {
            shards[rng.below(workers)].insert(v);
        }
        rng.shuffle(&mut shards);
        let mut forward = Sketch::new();
        for s in &shards {
            forward.merge(s);
        }
        let mut reverse = Sketch::new();
        for s in shards.iter().rev() {
            reverse.merge(s);
        }

        let want = sketch_line(&sequential);
        assert_eq!(sketch_line(&forward), want, "{workers}-way shard merge diverged");
        assert_eq!(sketch_line(&reverse), want, "reverse fold order diverged");

        // Quantiles and the MAD band are functions of the sketch alone,
        // so they agree exactly too.
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(
                sequential.quantile(q).map(f64::to_bits),
                forward.quantile(q).map(f64::to_bits),
                "quantile({q}) diverged"
            );
        }
        assert_eq!(
            sequential.median_mad().map(|(m, d)| (m.to_bits(), d.to_bits())),
            reverse.median_mad().map(|(m, d)| (m.to_bits(), d.to_bits())),
            "median/MAD diverged"
        );
    });
}
