//! Differential property suite for the hierarchical two-tier aggregation
//! tree (`agg/tree.rs`; seeded runner in `util::prop` — offline build, no
//! proptest crate, see docs/testing.md).
//!
//! Invariants (the tentpole's equivalence gate):
//! * The Mean/Mean tree *relays*: at every fanout, over random shapes and
//!   weights, it reproduces the flat `aggregate_weighted` fold
//!   **bit-for-bit** — the edge tier vanishes from the model function.
//! * The relay discipline composes with a buffered root across rounds:
//!   a `Buffered` root behind Mean edges equals the flat `Buffered`
//!   aggregator bitwise, including held/flushed rounds.
//! * Reducing edge tiers (trimmed mean, median, norm clipping) are
//!   deterministic and replay bit-for-bit, but are deliberately NOT the
//!   flat fold — the degenerate case is explicit, not accidental.
//! * With a runtime (`make artifacts`): a `--agg-tree` Mean/Mean engine
//!   run equals the flat engine bit-for-bit (all `RoundRecord` fields via
//!   `to_bits` + CSV), at a fanout randomized per case — tree topology is
//!   config, never observable in model outputs (determinism rule 6's
//!   tier-composition analogue).
//!
//! Knobs: `PROPTEST_CASES` scales case counts, `PROPTEST_SEED` replays.

use std::sync::Arc;

use fedcore::agg::{aggregate_weighted, AggPolicy, Aggregator, TreeSpec};
use fedcore::data::{self, Benchmark};
use fedcore::fl::{Engine, RunConfig, Strategy};
use fedcore::util::prop::{check, env_cases, env_seed};
use fedcore::util::rng::Rng;

fn gen_locals(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| (0..dim).map(|_| rng.normal() as f32).collect()).collect()
}

fn refs(v: &[Vec<f32>]) -> Vec<&[f32]> {
    v.iter().map(|x| x.as_slice()).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: dim {i}: {x} vs {y}");
    }
}

// ---------- the relay gate: Mean/Mean tree is the flat fold ----------

#[test]
fn proptest_tree_mean_mean_is_bitwise_flat_at_any_fanout() {
    check("tree-relay-bitwise", env_seed(0x73EE), env_cases(150), |rng, _| {
        let n = 1 + rng.below(24);
        let dim = 1 + rng.below(48);
        let locals = gen_locals(rng, n, dim);
        let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 3.0)).collect();
        let current: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let want = aggregate_weighted(&refs(&locals), &weights).unwrap();
        // Random fanout plus the structural extremes (1, n, > n).
        for fanout in [1, 1 + rng.below(n + 3), n.max(1), n + 7] {
            let mut tree = TreeSpec::mean(fanout).build(None);
            let (out, stats) = tree.aggregate_round(&current, &refs(&locals), &weights);
            assert_bits_eq(&want, &out.unwrap(), &format!("fanout {fanout}"));
            assert!(stats.is_quiet(), "a relay tree must report quiet stats");
        }
    });
}

/// Relay composition across rounds: Mean edges in front of a buffered
/// root must behave exactly like the flat buffered aggregator — holds,
/// flushes, momentum, and all — because the root sees the identical
/// contribution sequence.
#[test]
fn proptest_tree_relay_composes_with_buffered_root() {
    check("tree-buffered-root", env_seed(0x73EF), env_cases(100), |rng, _| {
        let dim = 1 + rng.below(16);
        let k = rng.below(7);
        let momentum = [0.0, 0.5][rng.below(2)];
        let root = AggPolicy::Buffered { k, momentum };
        let rounds: Vec<Vec<Vec<f32>>> =
            (0..2 + rng.below(5)).map(|_| gen_locals(rng, 1 + rng.below(4), dim)).collect();

        let mut flat = root.build(None);
        let mut tree = TreeSpec { fanout: 1 + rng.below(6), edge: AggPolicy::Mean, root }
            .build(None);
        let mut flat_params: Vec<f32> = vec![0.0; dim];
        let mut tree_params: Vec<f32> = vec![0.0; dim];
        for contributions in &rounds {
            let w = vec![1.0; contributions.len()];
            let (a, sa) = flat.aggregate_round(&flat_params, &refs(contributions), &w);
            let (b, sb) = tree.aggregate_round(&tree_params, &refs(contributions), &w);
            assert_eq!(sa, sb, "buffered stats diverged");
            assert_eq!(a.is_some(), b.is_some(), "flush rounds diverged");
            if let (Some(a), Some(b)) = (a, b) {
                assert_bits_eq(&a, &b, "buffered-root flush");
                flat_params = a;
                tree_params = b;
            }
        }
        match (flat.flush(&flat_params), tree.flush(&tree_params)) {
            (Some(a), Some(b)) => assert_bits_eq(&a, &b, "end-of-run flush"),
            (None, None) => {}
            _ => panic!("end-of-run flush presence diverged"),
        }
    });
}

// ---------- reducing tiers: deterministic, replayable, distinct ----------

#[test]
fn proptest_tree_reducing_edges_replay_and_differ_from_flat() {
    check("tree-reducing-replay", env_seed(0x73F0), env_cases(100), |rng, _| {
        // Shards of >= 4 contributions so both robust policies do real
        // per-shard rejection work (a 2-wide shard trims/rejects nothing).
        let fanout = 2 + rng.below(3);
        let n = 4 * fanout + rng.below(8);
        let dim = 2 + rng.below(16);
        let locals = gen_locals(rng, n, dim);
        let weights = vec![1.0; n];
        let current: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let edge = [
            AggPolicy::TrimmedMean { trim_frac: 0.25 },
            AggPolicy::CoordinateMedian,
        ][rng.below(2)];
        let spec = TreeSpec { fanout, edge, root: AggPolicy::Mean };
        let (a, sa) = spec.build(None).aggregate_round(&current, &refs(&locals), &weights);
        let (b, sb) = spec.build(None).aggregate_round(&current, &refs(&locals), &weights);
        assert_bits_eq(&a.clone().unwrap(), &b.unwrap(), "reducing-tree replay");
        assert_eq!(sa, sb);
        assert!(sa.rejected > 0, "robust edges must report per-shard rejections");
        let flat = aggregate_weighted(&refs(&locals), &weights).unwrap();
        assert_ne!(a.unwrap(), flat, "a reducing edge tier should not equal the flat fold");
    });
}

#[test]
fn proptest_tree_edge_clipping_counts_every_client() {
    check("tree-edge-clip", env_seed(0x73F1), env_cases(100), |rng, _| {
        let n = 1 + rng.below(12);
        let dim = 1 + rng.below(12);
        // Updates with norms well above the bound: every one must clip,
        // regardless of which shard it lands in.
        let current = vec![0.0f32; dim];
        let locals: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| 10.0 + rng.f32()).collect())
            .collect();
        let weights = vec![1.0; n];
        let spec = TreeSpec::mean(1 + rng.below(n + 2));
        let (out, stats) =
            spec.build(Some(1e-3)).aggregate_round(&current, &refs(&locals), &weights);
        assert!(out.is_some());
        assert_eq!(stats.clipped, n, "edge-tier clipping must see every client update");
    });
}

// ---------- engine differentials (runtime-backed) ----------

fn runtime_or_skip() -> Option<fedcore::runtime::Runtime> {
    fedcore::expt::try_runtime()
}

fn engine_cfg(rng: &mut Rng, case: usize) -> RunConfig {
    let strategies = [Strategy::FedAvg, Strategy::FedCore];
    RunConfig {
        strategy: strategies[case % strategies.len()],
        rounds: 2 + rng.below(2),
        epochs: 2 + rng.below(2),
        clients_per_round: 3 + rng.below(4),
        lr: 0.01,
        straggler_pct: 30.0,
        seed: rng.next_u64(),
        eval_every: 1,
        eval_cap: 128,
        ..RunConfig::default()
    }
}

fn assert_rounds_bitwise_equal(a: &fedcore::metrics::RunResult, b: &fedcore::metrics::RunResult) {
    assert_eq!(a.final_params, b.final_params, "final params diverged");
    assert_eq!(a.rounds.len(), b.rounds.len());
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {r} train_loss");
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "round {r} test_loss");
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "round {r} test_acc");
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "round {r} sim_time");
        assert_eq!(x.tail_time.to_bits(), y.tail_time.to_bits(), "round {r} tail_time");
        assert_eq!(x.client_times, y.client_times, "round {r} client_times");
        assert_eq!(x.dropped, y.dropped, "round {r} dropped");
        assert_eq!(x.agg_rejected, y.agg_rejected, "round {r} agg_rejected");
        assert_eq!(x.agg_clipped, y.agg_clipped, "round {r} agg_clipped");
        assert_eq!(x.coreset_clients, y.coreset_clients, "round {r} coreset_clients");
    }
    assert_eq!(a.to_csv(), b.to_csv(), "CSV serializations diverged");
}

/// The tentpole gate: a Mean/Mean `--agg-tree` engine run equals the flat
/// engine bit-for-bit (every round field + CSV), with the fanout
/// randomized per case — the tree topology never reaches the model.
#[test]
fn proptest_tree_engine_mean_mean_equals_flat_engine() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("tree-engine-relay", env_seed(0x73E0), env_cases(4), |rng, case| {
        let flat_cfg = engine_cfg(rng, case);
        let fanout = 1 + rng.below(8);
        let mut tree_cfg = flat_cfg.clone();
        tree_cfg.agg_tree = Some(TreeSpec::mean(fanout));

        let flat = Engine::new(&rt, &ds, flat_cfg).unwrap().run().unwrap();
        let tree = Engine::new(&rt, &ds, tree_cfg).unwrap().run().unwrap();
        assert_rounds_bitwise_equal(&flat, &tree);
    });
}

/// Robust-at-edge engine runs are deterministic: a median-edge tree
/// replays bit-for-bit from its seed, and two different fanouts are two
/// different (hierarchical) estimators.
#[test]
fn proptest_tree_engine_robust_edges_replay() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("tree-engine-robust", env_seed(0x73E1), env_cases(3), |rng, case| {
        let mut cfg = engine_cfg(rng, case);
        cfg.agg_tree = Some(TreeSpec {
            fanout: 2,
            edge: AggPolicy::CoordinateMedian,
            root: AggPolicy::Mean,
        });
        let a = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();
        let b = Engine::new(&rt, &ds, cfg).unwrap().run().unwrap();
        assert_rounds_bitwise_equal(&a, &b);
    });
}
