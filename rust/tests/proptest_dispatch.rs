//! Differential property suite for deterministic dispatch scheduling
//! (`exec/dispatch.rs`; seeded runner in `util::prop` — offline build,
//! no proptest crate, see docs/testing.md).
//!
//! Invariants:
//! * The work-stealing schedule is complete (every job placed exactly
//!   once), work-conserving (busy time = total cost; Graham bound
//!   `makespan ≤ total/W + max`), never slower than round-robin dealing,
//!   and degenerates to round-robin exactly on homogeneous costs.
//! * Schedules — and hence [`fedcore::exec::ScheduleTrace`] ledgers —
//!   are pure functions of `(policy, costs, workers)`: replays are
//!   bit-identical, including under `PROPTEST_SEED`.
//! * The dispatch/trace Executor APIs delegate through `&E` (the shared
//!   sweep-pool reference), and schedules are recorded at dispatch time
//!   even when jobs fail (no runtime needed).
//! * With a runtime (`make artifacts`): `WorkStealing` ≡ `RoundRobin` ≡
//!   `Sequential` **bit-for-bit** — final model bytes, every round
//!   record, the model CSV, and checkpoint files — across strategies,
//!   every `agg` policy, churn traces, and the overlap/quorum pipeline;
//!   schedule traces and the dispatch ledger CSV replay exactly from
//!   the seed; and one cross-subsystem cell (work-stealing + overlap
//!   quorum + trimmed mean + markov churn through `expt::run_cell_with`)
//!   replays bit-for-bit.
//!
//! Knobs: `PROPTEST_CASES` scales case counts, `PROPTEST_SEED` replays.

use std::sync::Arc;

use fedcore::agg::{AggPolicy, TreeSpec};
use fedcore::coreset::Method;
use fedcore::data::{self, Benchmark, FedDataset, Samples, Shard};
use fedcore::exec::{
    plan_schedule, ClientJob, DispatchPolicy, ExecContext, Executor, JobKind, OverlapConfig,
    Sharded,
};
use fedcore::fl::{Checkpoint, CoresetMode, Engine, LocalPlan, RunConfig, Strategy};
use fedcore::metrics::RunResult;
use fedcore::runtime::{ModelInfo, Runtime, RuntimeFactory, XDtype};
use fedcore::scenario::{ChurnModel, TraceSpec};
use fedcore::sim::Fleet;
use fedcore::util::prop::{check, env_cases, env_seed};
use fedcore::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    fedcore::expt::try_runtime()
}

fn random_costs(rng: &mut Rng) -> Vec<f64> {
    let n = rng.below(30);
    (0..n)
        .map(|_| {
            // Occasional zero-cost (dropped-plan) jobs; otherwise a
            // heavy-tailed mix so schedules actually differ.
            if rng.below(6) == 0 {
                0.0
            } else if rng.below(4) == 0 {
                rng.range_f64(5.0, 40.0)
            } else {
                rng.range_f64(0.1, 3.0)
            }
        })
        .collect()
}

// ---------- pure schedule invariants ----------

#[test]
fn proptest_dispatch_work_stealing_schedule_invariants() {
    check("dispatch-ws-invariants", env_seed(0xD15A), env_cases(200), |rng, _| {
        let costs = random_costs(rng);
        let workers = 1 + rng.below(6);
        let total: f64 = costs.iter().sum();
        let max = costs.iter().copied().fold(0.0f64, f64::max);
        let eps = 1e-9 * (1.0 + total);

        let rr = plan_schedule(DispatchPolicy::RoundRobin, &costs, workers);
        let ws = plan_schedule(DispatchPolicy::WorkStealing, &costs, workers);
        for s in [&rr, &ws] {
            // Complete placement on real workers, one slot per job.
            assert_eq!(s.assignment.len(), costs.len());
            assert!(s.assignment.iter().all(|&w| w < workers));
            // Each job occupies exactly its cost in virtual time.
            for i in 0..costs.len() {
                assert!(
                    (s.end[i] - s.start[i] - costs[i]).abs() <= eps,
                    "job {i} span {} != cost {}",
                    s.end[i] - s.start[i],
                    costs[i]
                );
            }
            // Work conservation and the trivial makespan lower bounds.
            assert!((s.busy_seconds() - total).abs() <= eps);
            assert!(s.makespan + eps >= max, "makespan below the largest job");
            assert!(s.makespan + eps >= total / workers as f64);
            let u = s.utilization();
            assert!((0.0..=1.0 + 1e-12).contains(&u), "utilization {u} out of range");
            // Steal accounting is exactly the away-from-home count.
            let away = s
                .assignment
                .iter()
                .enumerate()
                .filter(|(i, &w)| w != i % workers)
                .count();
            assert_eq!(s.steals(), away);
        }
        assert_eq!(rr.steals(), 0, "round-robin never steals");
        // Work stealing is work-conserving: Graham's list-scheduling
        // bound holds, and it never loses to round-robin dealing.
        assert!(
            ws.makespan <= total / workers as f64 + max + eps,
            "ws makespan {} violates the work-conserving bound",
            ws.makespan
        );
        assert!(
            ws.makespan <= rr.makespan + eps,
            "ws makespan {} exceeds rr {}",
            ws.makespan,
            rr.makespan
        );
        assert!(ws.idle_seconds() <= rr.idle_seconds() + workers as f64 * eps);
    });
}

#[test]
fn proptest_dispatch_homogeneous_costs_degenerate_to_round_robin() {
    check("dispatch-homogeneous-degenerate", env_seed(0xD15B), env_cases(100), |rng, _| {
        let n = rng.below(40);
        let workers = 1 + rng.below(6);
        let costs = vec![rng.range_f64(0.5, 5.0); n];
        let rr = plan_schedule(DispatchPolicy::RoundRobin, &costs, workers);
        let ws = plan_schedule(DispatchPolicy::WorkStealing, &costs, workers);
        // A balanced batch gives stealing nothing to do: the entire
        // schedule — placement, virtual times, accounting — is the
        // round-robin one, bit for bit.
        assert_eq!(ws, rr);
        assert_eq!(ws.steals(), 0);
    });
}

#[test]
fn proptest_dispatch_schedule_replay_is_deterministic() {
    check("dispatch-schedule-replay", env_seed(0xD15C), env_cases(100), |rng, _| {
        let costs = random_costs(rng);
        let workers = 1 + rng.below(6);
        for policy in [DispatchPolicy::RoundRobin, DispatchPolicy::WorkStealing] {
            let a = plan_schedule(policy, &costs, workers);
            let b = plan_schedule(policy, &costs, workers);
            // Full structural equality — assignments, virtual times,
            // busy vectors, makespan — with f64s compared exactly: the
            // schedule is a pure function of (policy, costs, workers).
            assert_eq!(a, b, "{} schedule did not replay", policy.label());
        }
    });
}

// ---------- pool lifecycle + `&E` delegation without a runtime ----------

/// A minimal context that never reaches a real runtime (the factory below
/// points at a directory with no artifacts, so workers fail fast).
fn tiny_ctx() -> Arc<ExecContext> {
    let shard = Shard {
        samples: Samples::Dense { x: vec![0.25; 8 * 4], dim: 4 },
        labels: vec![0; 8],
    };
    let data = Arc::new(FedDataset {
        model: "logreg".into(),
        clients: vec![shard.clone(), shard.clone()],
        test: shard,
    });
    let mut frng = Rng::new(1);
    let fleet = Arc::new(Fleet::new(&mut frng, vec![8, 8], 2, 30.0));
    let model = ModelInfo {
        name: "logreg".into(),
        param_size: 4,
        num_classes: 2,
        x_shape: vec![4],
        x_dtype: XDtype::F32,
        seq_len: 0,
        init_params: vec![0.0; 4],
        train_file: "logreg_train.hlo.txt".into(),
        feat_file: "logreg_feat.hlo.txt".into(),
        eval_file: "logreg_eval.hlo.txt".into(),
    };
    Arc::new(ExecContext {
        data,
        model,
        fleet,
        lr: 0.1,
        mu: 0.0,
        method: Method::FasterPam,
        coreset_workers: 1,
    })
}

#[test]
fn proptest_dispatch_trace_apis_delegate_through_shared_pool_refs() {
    check("dispatch-ref-delegation", env_seed(0xD15D), env_cases(8), |rng, _| {
        let workers = 2 + rng.below(3);
        let factory = RuntimeFactory::new("/nonexistent/fedcore-artifacts");
        let pool = Sharded::with_policy(workers, factory, DispatchPolicy::WorkStealing);
        // Everything below goes through `&pool` — the shared sweep-pool
        // executor — so the new dispatch/trace APIs must all delegate.
        let by_ref: &Sharded = &pool;
        assert_eq!(by_ref.dispatch_policy(), DispatchPolicy::WorkStealing);
        assert_eq!(Executor::workers(&by_ref), workers);
        by_ref.record_schedule(true);

        let ctx = tiny_ctx();
        let jobs: Vec<ClientJob> = (0..2)
            .map(|c| ClientJob {
                client: c,
                plan: LocalPlan::FullSet { epochs: 2 },
                global: Arc::new(vec![0.0; 4]),
                static_coreset: None,
                warm_medoids: None,
                rng: rng.split(c as u64),
            })
            .collect();
        // The jobs fail (no artifacts) — but the schedule was planned
        // and recorded at dispatch time, so instrumentation still works.
        assert!(by_ref.run_clients(&ctx, jobs).is_err());
        let stats = by_ref.last_client_dispatch().expect("client batch observed");
        assert_eq!(stats.workers, workers);
        assert_eq!(stats.jobs, 2);
        let trace = by_ref.take_schedule().expect("recording was on");
        assert_eq!(trace.len(), 2);
        assert!(trace.entries.iter().all(|e| e.kind == JobKind::Client && e.worker < workers));
        // Draining leaves an empty ledger; turning recording off stops it.
        assert!(by_ref.take_schedule().expect("still recording").is_empty());
        by_ref.record_schedule(false);
        assert!(by_ref.take_schedule().is_none());
    });
}

// ---------- runtime-gated: the dispatch differential harness ----------

fn agg_for(case: usize) -> (AggPolicy, Option<f64>) {
    // Cycle every aggregation policy through the differential, with a
    // norm-clip wrapper on alternating passes.
    let clip = if case % 2 == 0 { None } else { Some(2.5) };
    let policy = match (case / 2) % 4 {
        0 => AggPolicy::Mean,
        1 => AggPolicy::Buffered { k: 3, momentum: 0.2 },
        2 => AggPolicy::TrimmedMean { trim_frac: 0.1 },
        _ => AggPolicy::CoordinateMedian,
    };
    (policy, clip)
}

fn differential_cfg(rng: &mut Rng, case: usize) -> RunConfig {
    let strategies = [
        Strategy::FedCore,
        Strategy::FedAvgDS,
        Strategy::FedProx { mu: 0.1 },
        Strategy::FedAvg,
    ];
    let (aggregator, clip_norm) = agg_for(case);
    let trace = match rng.below(3) {
        0 => None,
        1 => Some(TraceSpec::from_model(
            ChurnModel::Markov {
                mean_on: rng.range_f64(2.0, 8.0),
                mean_off: rng.range_f64(0.5, 3.0),
                p_init_online: 0.8,
            },
            24.0,
            rng.next_u64(),
        )),
        _ => Some(TraceSpec::from_model(
            ChurnModel::HeavyTail {
                mean_on: rng.range_f64(2.0, 6.0),
                min_off: 0.5,
                alpha: rng.range_f64(1.2, 2.5),
            },
            24.0,
            rng.next_u64(),
        )),
    };
    let overlap = (rng.below(2) == 0).then(|| OverlapConfig {
        quorum: rng.range_f64(0.4, 1.0),
        max_staleness: rng.below(3),
        alpha: 1.0,
    });
    // Hierarchical aggregation at a random fanout on half the cases: the
    // dispatch differential must hold through the tree seam too (shards
    // are contiguous in fold order, so worker count cannot leak in).
    // Buffered tiers may only run at the root (edges rebuild every round).
    let agg_tree = (rng.below(2) == 0).then(|| {
        let fanout = 1 + rng.below(6);
        match aggregator {
            AggPolicy::Buffered { .. } => {
                TreeSpec { fanout, edge: AggPolicy::Mean, root: aggregator }
            }
            edge => TreeSpec { fanout, edge, root: AggPolicy::Mean },
        }
    });
    RunConfig {
        strategy: strategies[case % strategies.len()],
        rounds: 1 + rng.below(2),
        epochs: 2 + rng.below(2),
        clients_per_round: 3 + rng.below(4),
        lr: 0.01,
        straggler_pct: [10.0, 30.0][rng.below(2)],
        seed: rng.next_u64(),
        coreset_method: Method::FasterPam,
        coreset_mode: [CoresetMode::Adaptive, CoresetMode::Static][rng.below(2)],
        eval_every: 1,
        eval_cap: 128,
        workers: 1,
        dispatch: DispatchPolicy::RoundRobin,
        trace,
        overlap,
        aggregator,
        clip_norm,
        agg_tree,
        adaptive_quorum: overlap.is_some() && rng.below(2) == 0,
        verbose: false,
        ..RunConfig::default()
    }
}

/// Serialized checkpoint bytes of a run's final model (written through
/// the real `Checkpoint` writer, then read back raw).
fn checkpoint_bytes(res: &RunResult, tag: &str) -> Vec<u8> {
    // Unique per call: tests run concurrently in one process, so the
    // pid alone cannot disambiguate scratch files.
    static SCRATCH: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    let nonce = SCRATCH.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let path = std::env::temp_dir().join(format!(
        "fedcore-dispatch-{}-{tag}-{nonce}.ckpt",
        std::process::id()
    ));
    Checkpoint::new(res.benchmark.clone(), res.rounds.len() as u64, res.final_params.clone())
        .save(&path)
        .expect("writing checkpoint");
    let bytes = std::fs::read(&path).expect("reading checkpoint back");
    let _ = std::fs::remove_file(&path);
    bytes
}

/// The dispatch determinism contract: model bytes, every round record,
/// and the model CSV are bit-identical; only the dispatch diagnostics
/// (`steal_count` / `worker_idle`, exported via `to_dispatch_csv`) may
/// differ between executors.
fn assert_model_outputs_bitwise_equal(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.final_params.len(), b.final_params.len(), "{what}: param count");
    for (i, (x, y)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: final param {i}: {x} vs {y}");
    }
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{what} round {r} loss");
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{what} round {r} test_loss");
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "{what} round {r} test_acc");
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{what} round {r} sim_time");
        assert_eq!(x.tail_time.to_bits(), y.tail_time.to_bits(), "{what} round {r} tail_time");
        assert_eq!(x.client_times, y.client_times, "{what} round {r} client_times");
        assert_eq!(x.dropped, y.dropped, "{what} round {r} dropped");
        assert_eq!(x.churn_dropped, y.churn_dropped, "{what} round {r} churn_dropped");
        assert_eq!(x.stale_folded, y.stale_folded, "{what} round {r} stale_folded");
        assert_eq!(x.stale_discarded, y.stale_discarded, "{what} round {r} stale_discarded");
        assert_eq!(x.agg_rejected, y.agg_rejected, "{what} round {r} agg_rejected");
        assert_eq!(x.agg_clipped, y.agg_clipped, "{what} round {r} agg_clipped");
        assert_eq!(x.coreset_clients, y.coreset_clients, "{what} round {r} coreset_clients");
    }
    assert_eq!(a.to_csv(), b.to_csv(), "{what}: model CSV diverged");
    assert_eq!(
        checkpoint_bytes(a, "a"),
        checkpoint_bytes(b, "b"),
        "{what}: checkpoint bytes diverged"
    );
}

/// The centerpiece: `WorkStealing` ≡ `RoundRobin` ≡ `Sequential`
/// bit-for-bit across strategies, every aggregation policy, churn
/// traces, and the overlap pipeline.
#[test]
fn proptest_dispatch_policies_bitwise_equivalent() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("dispatch-policy-equivalence", env_seed(0xD15E), env_cases(8), |rng, case| {
        let mut cfg = differential_cfg(rng, case);
        let seq = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();

        cfg.workers = 2 + rng.below(3);
        cfg.dispatch = DispatchPolicy::RoundRobin;
        let rr = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();

        cfg.dispatch = DispatchPolicy::WorkStealing;
        let ws = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();

        let what = format!(
            "{} agg={} workers={}",
            seq.strategy,
            cfg.aggregator.label(),
            cfg.workers
        );
        assert_model_outputs_bitwise_equal(&seq, &rr, &format!("{what} [seq vs rr]"));
        assert_model_outputs_bitwise_equal(&seq, &ws, &format!("{what} [seq vs ws]"));
        assert_model_outputs_bitwise_equal(&rr, &ws, &format!("{what} [rr vs ws]"));
    });
}

/// Schedule-trace replay: the work-stealing ledger (placement, virtual
/// times, steal counts) and the per-round dispatch CSV are pure
/// functions of the seed.
#[test]
fn proptest_dispatch_trace_replays_deterministically() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("dispatch-trace-replay", env_seed(0xD15F), env_cases(4), |rng, case| {
        let mut cfg = differential_cfg(rng, case);
        cfg.workers = 3;
        cfg.dispatch = DispatchPolicy::WorkStealing;
        let one_run = || {
            let exec =
                Sharded::with_policy(cfg.workers, rt.factory(), DispatchPolicy::WorkStealing);
            let engine = Engine::with_executor(&rt, &ds, cfg.clone(), exec).unwrap();
            engine.executor().record_schedule(true);
            let result = engine.run().unwrap();
            let trace = engine.executor().take_schedule().expect("recording was on");
            (result, trace)
        };
        let (res_a, trace_a) = one_run();
        let (res_b, trace_b) = one_run();
        assert_eq!(trace_a, trace_b, "schedule trace did not replay");
        assert!(!trace_a.is_empty(), "a real run must record dispatches");
        assert_eq!(
            res_a.to_dispatch_csv(),
            res_b.to_dispatch_csv(),
            "dispatch ledger CSV did not replay"
        );
        assert_eq!(res_a.to_csv(), res_b.to_csv(), "model CSV did not replay");
        // The ledger and the per-round columns agree: each round's last
        // client entry carries that round's cumulative steal count.
        for rec in &res_a.rounds {
            let batch_last = trace_a
                .entries
                .iter()
                .rfind(|e| e.kind == JobKind::Client && e.round == rec.round);
            if let Some(e) = batch_last {
                assert_eq!(
                    e.steal_count, rec.steal_count,
                    "round {} ledger/record steal mismatch",
                    rec.round
                );
            }
        }
    });
}

/// Cross-subsystem composition (satellite): one cell driving
/// work-stealing dispatch + the overlap quorum + the trimmed-mean
/// aggregator + a markov churn trace through `expt::run_cell_with`,
/// replayed bit-for-bit on the same seed.
#[test]
fn proptest_dispatch_cross_subsystem_cell_replays() {
    let Some(rt) = runtime_or_skip() else { return };
    let compose = |run: &mut RunConfig| {
        run.workers = 3;
        run.dispatch = DispatchPolicy::WorkStealing;
        run.overlap = Some(OverlapConfig { quorum: 0.6, max_staleness: 2, alpha: 1.0 });
        run.aggregator = AggPolicy::TrimmedMean { trim_frac: 0.1 };
        run.trace = Some(TraceSpec::from_model(
            ChurnModel::Markov { mean_on: 4.0, mean_off: 1.5, p_init_online: 0.9 },
            24.0,
            17,
        ));
    };
    let bench = Benchmark::Synthetic { alpha: 1.0, beta: 1.0 };
    let a = fedcore::expt::run_cell_with(&rt, bench, 30.0, env_seed(21), compose).unwrap();
    let b = fedcore::expt::run_cell_with(&rt, bench, 30.0, env_seed(21), compose).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_model_outputs_bitwise_equal(x, y, &format!("{} cell replay", x.strategy));
        assert_eq!(
            x.to_dispatch_csv(),
            y.to_dispatch_csv(),
            "{}: dispatch ledger did not replay",
            x.strategy
        );
    }
}
