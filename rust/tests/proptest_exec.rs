//! Property suites for the exec subsystem (seeded runner in `util::prop`;
//! offline build, no proptest crate — see DESIGN.md "Offline-build note").
//!
//! Invariants:
//! * `aggregate` is order-independent (up to f32 rounding) — the algebraic
//!   property that makes order-preserving reduce sufficient for
//!   determinism.
//! * The sharded worker pool is safe without artifacts: empty rounds
//!   succeed, missing-runtime errors surface as `Err` (never a hang or a
//!   panic), and shutdown is clean for any worker count.
//! * `Sharded` and `Sequential` executors produce identical `RunResult`
//!   round records — bit-for-bit — for random configs and worker counts
//!   (runs only when `make artifacts` has been run, like the other
//!   runtime suites).
//!
//! Knobs (proptest-compatible, per the testing-strategy doc):
//! `PROPTEST_CASES` scales case counts, `PROPTEST_SEED` replays a run.

use std::sync::Arc;

use fedcore::coreset::Method;
use fedcore::data::{self, Benchmark, FedDataset, Samples, Shard};
use fedcore::exec::{ClientJob, EvalJob, ExecContext, Executor, Sharded};
use fedcore::fl::{aggregate, CoresetMode, Engine, LocalPlan, RunConfig, Strategy};
use fedcore::runtime::{ModelInfo, Runtime, RuntimeFactory, XDtype};
use fedcore::sim::Fleet;
use fedcore::util::prop::{check, env_cases, env_seed};
use fedcore::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    fedcore::expt::try_runtime()
}

// ---------- aggregation algebra ----------

#[test]
fn proptest_exec_aggregate_is_order_independent() {
    check("exec-agg-order", env_seed(0xA9E6), env_cases(50), |rng, _| {
        let k = 1 + rng.below(8);
        let dim = 1 + rng.below(64);
        let mut locals: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = locals.iter().map(|v| v.as_slice()).collect();
        let a = aggregate(&refs).unwrap();
        rng.shuffle(&mut locals);
        let refs: Vec<&[f32]> = locals.iter().map(|v| v.as_slice()).collect();
        let b = aggregate(&refs).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!(
                (x - y).abs() <= 1e-6 * (1.0 + x.abs()),
                "aggregate not order-independent: {x} vs {y}"
            );
        }
    });
}

// ---------- pool lifecycle without a runtime ----------

/// A minimal context that never reaches a real runtime (the factory below
/// points at a directory with no artifacts, so workers fail fast).
fn tiny_ctx() -> Arc<ExecContext> {
    let shard = Shard {
        samples: Samples::Dense { x: vec![0.25; 8 * 4], dim: 4 },
        labels: vec![0; 8],
    };
    let data = Arc::new(FedDataset {
        model: "logreg".into(),
        clients: vec![shard.clone()],
        test: shard,
    });
    let mut frng = Rng::new(1);
    let fleet = Arc::new(Fleet::new(&mut frng, vec![8], 2, 30.0));
    let model = ModelInfo {
        name: "logreg".into(),
        param_size: 4,
        num_classes: 2,
        x_shape: vec![4],
        x_dtype: XDtype::F32,
        seq_len: 0,
        init_params: vec![0.0; 4],
        train_file: "logreg_train.hlo.txt".into(),
        feat_file: "logreg_feat.hlo.txt".into(),
        eval_file: "logreg_eval.hlo.txt".into(),
    };
    Arc::new(ExecContext {
        data,
        model,
        fleet,
        lr: 0.1,
        mu: 0.0,
        method: Method::FasterPam,
        coreset_workers: 1,
    })
}

#[test]
fn proptest_exec_pool_lifecycle_without_artifacts() {
    check("exec-pool-lifecycle", env_seed(0xB00F), env_cases(8), |rng, _| {
        let workers = 1 + rng.below(4);
        let factory = RuntimeFactory::new("/nonexistent/fedcore-artifacts");
        let pool = Sharded::new(workers, factory);
        assert_eq!(pool.workers(), workers);
        let ctx = tiny_ctx();

        // Empty rounds are a no-op for any worker count.
        for _ in 0..1 + rng.below(3) {
            assert!(pool.run_clients(&ctx, vec![]).unwrap().is_empty());
            assert!(pool.run_evals(&ctx, vec![]).unwrap().is_empty());
        }

        // A real job must surface the missing-runtime failure as Err —
        // never a hang or a panic — and the pool must stay usable.
        let job = ClientJob {
            client: 0,
            plan: LocalPlan::FullSet { epochs: 2 },
            global: Arc::new(vec![0.0; 4]),
            static_coreset: None,
            warm_medoids: None,
            rng: rng.split(7),
        };
        assert!(pool.run_clients(&ctx, vec![job]).is_err());
        let eval = EvalJob { params: Arc::new(vec![0.0; 4]), start: 0, end: 4 };
        assert!(pool.run_evals(&ctx, vec![eval]).is_err());
        assert!(pool.run_clients(&ctx, vec![]).is_ok(), "pool poisoned by a failed job");
        // `pool` drops here: shutdown + join must not deadlock.
    });
}

// ---------- sharded ≡ sequential (runtime-backed) ----------

#[test]
fn proptest_exec_sharded_matches_sequential() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    let strategies = [
        Strategy::FedCore,
        Strategy::FedAvgDS,
        Strategy::FedProx { mu: 0.1 },
        Strategy::FedAvg,
    ];
    check("exec-equivalence", env_seed(0xE8EC), env_cases(4), |rng, case| {
        let cfg = RunConfig {
            strategy: strategies[case % strategies.len()],
            rounds: 1 + rng.below(2),
            epochs: 2 + rng.below(2),
            clients_per_round: 2 + rng.below(4),
            lr: 0.01,
            straggler_pct: [10.0, 30.0][rng.below(2)],
            seed: rng.next_u64(),
            coreset_method: [Method::FasterPam, Method::Random][rng.below(2)],
            coreset_mode: [CoresetMode::Adaptive, CoresetMode::Static][rng.below(2)],
            eval_every: 1,
            eval_cap: 128,
            workers: 1,
            trace: None,
            overlap: None,
            verbose: false,
            ..RunConfig::default()
        };
        let seq = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();

        let workers = 2 + rng.below(3);
        let exec = Sharded::new(workers, rt.factory());
        let par = Engine::with_executor(&rt, &ds, cfg.clone(), exec).unwrap().run().unwrap();

        assert_eq!(
            seq.final_params, par.final_params,
            "{} × {workers} workers: final params diverged",
            seq.strategy
        );
        assert_eq!(seq.rounds.len(), par.rounds.len());
        for (a, b) in seq.rounds.iter().zip(&par.rounds) {
            let r = a.round;
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "round {r} train_loss");
            assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "round {r} test_loss");
            assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits(), "round {r} test_acc");
            assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "round {r} sim_time");
            assert_eq!(a.dropped, b.dropped, "round {r} dropped");
            assert_eq!(a.coreset_clients, b.coreset_clients, "round {r} coreset_clients");
            assert_eq!(
                a.mean_compression.to_bits(),
                b.mean_compression.to_bits(),
                "round {r} mean_compression"
            );
            assert_eq!(a.client_times, b.client_times, "round {r} client_times");
        }
    });
}

#[test]
fn proptest_exec_engine_workers_setting_matches_explicit_executor() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 0.5, beta: 0.5 },
        0.12,
        &rt.manifest().vocab,
        13,
    ));
    let base = RunConfig {
        strategy: Strategy::FedCore,
        rounds: 2,
        epochs: 2,
        clients_per_round: 4,
        lr: 0.01,
        straggler_pct: 30.0,
        seed: 21,
        coreset_method: Method::FasterPam,
        coreset_mode: CoresetMode::Adaptive,
        eval_every: 1,
        eval_cap: 128,
        workers: 1,
        trace: None,
        overlap: None,
        verbose: false,
        ..RunConfig::default()
    };
    // `workers: N` in the config must behave exactly like handing the
    // engine a Sharded executor of N workers.
    let mut via_cfg = base.clone();
    via_cfg.workers = 3;
    let a = Engine::new(&rt, &ds, via_cfg).unwrap().run().unwrap();
    let b = Engine::with_executor(&rt, &ds, base, Sharded::new(3, rt.factory()))
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(a.final_params, b.final_params);
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits());
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits());
    }
}

/// Cross-run pool reuse (ROADMAP): one shared `Sharded` pool driving a
/// whole sweep of engines — via the `&pool` executor impl — must produce
/// results bit-identical to building a fresh pool per engine. This is
/// what lets `expt::run_cell` and the CLI sweep compile each worker's
/// runtime once for all strategies.
#[test]
fn proptest_exec_shared_pool_matches_per_engine_pools() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 0.5, beta: 0.5 },
        0.12,
        &rt.manifest().vocab,
        13,
    ));
    let strategies = [Strategy::FedAvg, Strategy::FedCore, Strategy::FedAvgDS];
    let cfg_for = |strategy| RunConfig {
        strategy,
        rounds: 2,
        epochs: 2,
        clients_per_round: 4,
        lr: 0.01,
        straggler_pct: 30.0,
        seed: 23,
        eval_every: 1,
        eval_cap: 128,
        ..RunConfig::default()
    };
    // One pool, three engines — the sweep shape.
    let pool = Sharded::new(3, rt.factory());
    let shared: Vec<_> = strategies
        .iter()
        .map(|&s| {
            Engine::with_executor(&rt, &ds, cfg_for(s), &pool).unwrap().run().unwrap()
        })
        .collect();
    // Fresh pool per engine — the old per-engine behaviour.
    for (strategy, a) in strategies.iter().zip(&shared) {
        let b = Engine::with_executor(&rt, &ds, cfg_for(*strategy), Sharded::new(3, rt.factory()))
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(
            a.final_params, b.final_params,
            "{}: shared pool diverged from per-engine pool",
            a.strategy
        );
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
            assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "round {}", x.round);
            assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "round {}", x.round);
            assert_eq!(x.client_times, y.client_times, "round {}", x.round);
        }
        assert_eq!(a.to_csv(), b.to_csv(), "{}: CSV diverged", a.strategy);
    }
}
