//! Runtime smoke tests: load every AOT artifact through the PJRT CPU
//! client and check numerics against known ground truth. This is the
//! rust half of the python/tests contract — if these pass, the full
//! python→HLO→rust round-trip is sound.
//!
//! Requires `make artifacts` to have run (skips otherwise, like the
//! python suite does).

use fedcore::runtime::{Runtime, XBatch};

fn runtime_or_skip() -> Option<Runtime> {
    fedcore::expt::try_runtime()
}

#[test]
fn manifest_models_present() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = rt.manifest();
    assert_eq!(m.train_batch, 8);
    assert_eq!(m.feat_batch, 64);
    assert_eq!(m.feature_dim, 64);
    assert_eq!(m.pairwise_tile, 128);
    assert_eq!(m.vocab.len(), 64);
    for name in ["logreg", "mnist", "shake"] {
        assert!(m.models.contains_key(name), "missing model {name}");
    }
}

#[test]
fn warmup_compiles_all_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    rt.warmup().expect("warmup");
    assert_eq!(rt.stats().compile_count, 10);
}

#[test]
fn pairwise_tile_matches_cpu_reference() {
    let Some(rt) = runtime_or_skip() else { return };
    let t = rt.manifest().pairwise_tile;
    let c = rt.manifest().pairwise_dim;
    // Deterministic pseudo-random features.
    let mut rng = fedcore::util::rng::Rng::new(42);
    let a: Vec<f32> = (0..t * c).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..t * c).map(|_| rng.normal() as f32).collect();
    let out = rt.pairwise_tile(&a, &b).expect("pairwise");
    assert_eq!(out.len(), t * t);
    // CPU reference distance for a few spot pairs.
    for &(i, j) in &[(0usize, 0usize), (1, 7), (100, 3), (127, 127)] {
        let mut d2 = 0.0f64;
        for k in 0..c {
            let diff = (a[i * c + k] - b[j * c + k]) as f64;
            d2 += diff * diff;
        }
        let want = d2.sqrt() as f32;
        let got = out[i * t + j];
        assert!(
            (got - want).abs() < 1e-3 * (1.0 + want),
            "pair ({i},{j}): got {got}, want {want}"
        );
    }
}

#[test]
fn logreg_train_step_descends_and_matches_shapes() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.manifest().model("logreg").unwrap().clone();
    let b = rt.manifest().train_batch;
    let mut params = model.init_params.clone();
    let mut rng = fedcore::util::rng::Rng::new(1);
    let x: Vec<f32> = (0..b * 60).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.below(10) as i32).collect();
    let w = vec![1.0f32; b];

    let first = rt
        .train_step(&model, &params, &params, &XBatch::F32(x.clone()), &y, &w, 0.1, 0.0)
        .expect("step");
    assert_eq!(first.params.len(), model.param_size);
    // Zero-init logreg on 10 classes: first loss must be ln(10).
    assert!(
        (first.loss - (10.0f32).ln()).abs() < 1e-4,
        "initial loss {} != ln(10)",
        first.loss
    );
    params = first.params;
    let mut last = first.loss;
    for _ in 0..30 {
        let out = rt
            .train_step(&model, &params, &params, &XBatch::F32(x.clone()), &y, &w, 0.1, 0.0)
            .expect("step");
        params = out.params;
        last = out.loss;
    }
    assert!(last < 0.8 * (10.0f32).ln(), "loss did not descend: {last}");
}

#[test]
fn logreg_prox_term_shrinks_update() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.manifest().model("logreg").unwrap().clone();
    let b = rt.manifest().train_batch;
    let mut rng = fedcore::util::rng::Rng::new(2);
    let x: Vec<f32> = (0..b * 60).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.below(10) as i32).collect();
    let w = vec![1.0f32; b];
    // params away from gparams=0: prox must pull the result toward 0.
    let params = vec![0.5f32; model.param_size];
    let gparams = vec![0.0f32; model.param_size];
    let no_prox = rt
        .train_step(&model, &params, &gparams, &XBatch::F32(x.clone()), &y, &w, 0.05, 0.0)
        .unwrap();
    let with_prox = rt
        .train_step(&model, &params, &gparams, &XBatch::F32(x), &y, &w, 0.05, 1.0)
        .unwrap();
    let norm = |v: &[f32]| v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    assert!(norm(&with_prox.params) < norm(&no_prox.params));
}

#[test]
fn grad_features_shape_and_pad() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.manifest().model("logreg").unwrap().clone();
    let f = rt.manifest().feat_batch;
    let c = rt.manifest().feature_dim;
    let mut rng = fedcore::util::rng::Rng::new(3);
    let x: Vec<f32> = (0..f * 60).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..f).map(|_| rng.below(10) as i32).collect();
    let out = rt
        .grad_features(&model, &model.init_params, &XBatch::F32(x), &y)
        .expect("feat");
    assert_eq!(out.features.len(), f * c);
    assert_eq!(out.losses.len(), f);
    // Columns >= 10 are zero padding for logreg.
    for row in 0..f {
        for col in 10..c {
            assert_eq!(out.features[row * c + col], 0.0, "row {row} col {col}");
        }
    }
    // Zero-init params: feature rows are softmax(0) - onehot = 0.1 - e_y.
    for row in 0..4 {
        for col in 0..10 {
            let want = if y[row] as usize == col { 0.1 - 1.0 } else { 0.1 };
            let got = out.features[row * c + col];
            assert!((got - want).abs() < 1e-5, "row {row} col {col}: {got} vs {want}");
        }
    }
}

#[test]
fn evaluate_mask_semantics() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.manifest().model("logreg").unwrap().clone();
    let f = rt.manifest().feat_batch;
    let mut rng = fedcore::util::rng::Rng::new(4);
    let x: Vec<f32> = (0..f * 60).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..f).map(|_| rng.below(10) as i32).collect();
    let full = rt
        .evaluate(&model, &model.init_params, &XBatch::F32(x.clone()), &y, &vec![1.0; f])
        .unwrap();
    assert_eq!(full.count as usize, f);
    let mut mask = vec![0.0f32; f];
    mask[0] = 1.0;
    let one = rt
        .evaluate(&model, &model.init_params, &XBatch::F32(x), &y, &mask)
        .unwrap();
    assert_eq!(one.count as usize, 1);
    assert!(one.loss_sum <= full.loss_sum + 1e-6);
    // zero-init logreg: loss is exactly ln(10) per sample
    assert!((one.loss_sum - (10.0f64).ln()).abs() < 1e-4);
}

#[test]
fn mnist_cnn_and_shake_lstm_execute() {
    let Some(rt) = runtime_or_skip() else { return };
    let b = rt.manifest().train_batch;
    let mut rng = fedcore::util::rng::Rng::new(5);

    // CNN: one train step must run and return finite loss.
    let mnist = rt.manifest().model("mnist").unwrap().clone();
    let x: Vec<f32> = (0..b * 784).map(|_| rng.f32()).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.below(10) as i32).collect();
    let out = rt
        .train_step(&mnist, &mnist.init_params, &mnist.init_params, &XBatch::F32(x), &y, &vec![1.0; b], 0.03, 0.0)
        .expect("mnist step");
    assert!(out.loss.is_finite() && out.loss > 0.0);

    // LSTM: token inputs, per-position labels.
    let shake = rt.manifest().model("shake").unwrap().clone();
    let s = shake.seq_len;
    let x: Vec<i32> = (0..b * s).map(|_| rng.below(64) as i32).collect();
    let y: Vec<i32> = (0..b * s).map(|_| rng.below(64) as i32).collect();
    let out = rt
        .train_step(&shake, &shake.init_params, &shake.init_params, &XBatch::I32(x), &y, &vec![1.0; b], 0.03, 0.0)
        .expect("shake step");
    assert!(out.loss.is_finite() && out.loss > 0.0);
    // Random 64-way labels: loss should be near ln(64).
    assert!((out.loss - (64.0f32).ln()).abs() < 1.0, "loss {}", out.loss);
}

#[test]
fn shape_mismatch_is_error_not_ub() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.manifest().model("logreg").unwrap().clone();
    let bad = rt.train_step(
        &model,
        &model.init_params,
        &model.init_params,
        &XBatch::F32(vec![0.0; 3]), // wrong length
        &[0; 8],
        &[1.0; 8],
        0.1,
        0.0,
    );
    assert!(bad.is_err());
}
