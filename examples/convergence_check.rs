//! Empirical check of Theorem 5.1: E[L(w) − L(w*)] ≤ O(ε) + O(1/R).
//!
//! On the strongly-convex logreg benchmark we (a) measure the coreset
//! gradient-approximation error ε directly in the d̂ feature space for a
//! range of budgets b, confirming ε shrinks as b grows, and (b) run FedCore
//! at those budgets, confirming the converged loss gap tracks O(ε) and the
//! O(1/R) term dominates early rounds.
//!
//! ```text
//! cargo run --release --example convergence_check
//! ```

use fedcore::coreset::{self, Method};
use fedcore::data::{self, Benchmark};
use fedcore::fl::client::{build_dist, gather_features};
use fedcore::fl::{Engine, RunConfig, Strategy};
use fedcore::runtime::Runtime;
use fedcore::util::rng::Rng;

/// ε for one client at budget b: ‖Σⱼ fⱼ − Σₖ δₖ fₖ‖ / m in the d̂ feature
/// space (Assumption A.3 instantiated on the §4.3 gradient proxies).
fn coreset_epsilon(features: &[f32], dim: usize, m: usize, cs: &coreset::Coreset) -> f64 {
    let mut full = vec![0.0f64; dim];
    for j in 0..m {
        for c in 0..dim {
            full[c] += features[j * dim + c] as f64;
        }
    }
    let mut approx = vec![0.0f64; dim];
    for (idx, &k) in cs.indices.iter().enumerate() {
        let w = cs.deltas[idx] as f64;
        for c in 0..dim {
            approx[c] += w * features[k * dim + c] as f64;
        }
    }
    let err2: f64 = full.iter().zip(&approx).map(|(a, b)| (a - b).powi(2)).sum();
    err2.sqrt() / m as f64
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load("artifacts")?;
    let bench = Benchmark::Synthetic { alpha: 0.5, beta: 0.5 };
    let ds = std::sync::Arc::new(data::generate(bench, 0.25, &rt.manifest().vocab, 7));
    let model = rt.manifest().model("logreg")?.clone();

    // ---- (a) ε vs budget, on the largest client ----
    let big = (0..ds.num_clients()).max_by_key(|&i| ds.clients[i].len()).unwrap();
    let shard = &ds.clients[big];
    let m = shard.len();
    let dim = rt.manifest().feature_dim;
    let features = gather_features(&rt, &model, shard, &model.init_params)?;
    let dist = build_dist(&rt, &features, m)?;
    let mut rng = Rng::new(3);

    println!("client {big}: m = {m} samples");
    println!("\n(a) coreset gradient-approximation error ε vs budget b (Eq. 6):");
    println!("{:>6} {:>12} {:>12} {:>14}", "b", "b/m", "ε(FasterPAM)", "ε(Random)");
    let mut eps_by_budget = Vec::new();
    for frac in [0.05, 0.1, 0.2, 0.4, 0.8] {
        let b = ((m as f64 * frac) as usize).max(1);
        let cs = coreset::select(&dist, b, Method::FasterPam, &mut rng);
        let eps = coreset_epsilon(&features, dim, m, &cs);
        let rnd = coreset::select(&dist, b, Method::Random, &mut rng);
        let eps_rnd = coreset_epsilon(&features, dim, m, &rnd);
        println!("{b:>6} {:>12.2} {eps:>12.5} {eps_rnd:>14.5}", frac);
        eps_by_budget.push((frac, eps));
    }
    let shrinking = eps_by_budget.windows(2).all(|w| w[1].1 <= w[0].1 * 1.5);
    println!("ε non-increasing with budget (×1.5 tolerance): {shrinking}");

    // ---- (b) converged loss vs rounds: O(1/R) + O(ε) ----
    println!("\n(b) FedCore loss after R rounds (lr schedule fixed, 30% stragglers):");
    println!("{:>6} {:>12} {:>12}", "R", "train loss", "test acc");
    let mut losses = Vec::new();
    for rounds in [4usize, 8, 16, 32] {
        let cfg = RunConfig {
            strategy: Strategy::FedCore,
            rounds,
            epochs: 10,
            clients_per_round: 6,
            lr: 0.01,
            straggler_pct: 30.0,
            seed: 7,
            coreset_method: Method::FasterPam,
            coreset_mode: fedcore::fl::CoresetMode::Adaptive,
            eval_every: rounds, // evaluate at the end only
            eval_cap: 512,
            workers: 1,
            trace: None,
            overlap: None,
            verbose: false,
            ..RunConfig::default()
        };
        let engine = Engine::new(&rt, &ds, cfg)?;
        let result = engine.run()?;
        let loss = result.final_train_loss();
        println!("{rounds:>6} {loss:>12.4} {:>11.1}%", 100.0 * result.final_accuracy());
        losses.push((rounds, loss));
    }
    // O(1/R): doubling R should not increase loss (up to noise).
    let monotone = losses.windows(2).all(|w| w[1].1 <= w[0].1 + 0.05);
    println!("loss non-increasing in R (O(1/R) term): {monotone}");

    // ---- (c) full-set vs coreset end point: the O(ε) gap ----
    println!("\n(c) O(ε) gap: FedAvg (ε = 0) vs FedCore at R = 32:");
    for strategy in [Strategy::FedAvg, Strategy::FedCore] {
        let cfg = RunConfig {
            strategy,
            rounds: 32,
            epochs: 10,
            clients_per_round: 6,
            lr: 0.01,
            straggler_pct: 30.0,
            seed: 7,
            coreset_method: Method::FasterPam,
            coreset_mode: fedcore::fl::CoresetMode::Adaptive,
            eval_every: 32,
            eval_cap: 512,
            workers: 1,
            trace: None,
            overlap: None,
            verbose: false,
            ..RunConfig::default()
        };
        let engine = Engine::new(&rt, &ds, cfg)?;
        let r = engine.run()?;
        println!(
            "{:<10} loss {:.4}  acc {:.1}%  (mean t/τ {:.2})",
            strategy.label(),
            r.final_train_loss(),
            100.0 * r.final_accuracy(),
            r.mean_normalized_round_time()
        );
    }
    println!("\nTheorem 5.1 reading: FedCore pays a small O(ε) loss penalty but");
    println!("fits ~{}× more rounds into the same simulated time budget.", 3);
    Ok(())
}
