//! Quickstart: the smallest complete FedCore experiment.
//!
//! Loads the AOT artifacts, generates a small heterogeneous Synthetic(1,1)
//! federation, and trains it with FedCore under a 30%-straggler deadline —
//! then prints what the coreset machinery did each round.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use fedcore::config::ExperimentConfig;
use fedcore::data::{self, Benchmark};
use fedcore::fl::{Engine, Strategy};
use fedcore::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. The PJRT runtime: compiles artifacts/*.hlo.txt once, Python never runs.
    let rt = Runtime::load("artifacts")?;

    // 2. A small federation: 8 clients, FedProx-style Synthetic(1,1) data,
    //    power-law sizes, logistic-regression model.
    let mut cfg = ExperimentConfig::scaled_preset(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.25,
    )
    .with_strategy(Strategy::FedCore);
    cfg.run.rounds = 15;
    cfg.run.lr = 0.01; // a few rounds only, so step faster than the paper's 0.001
    cfg.run.straggler_pct = 30.0;
    cfg.run.verbose = false;
    let ds = std::sync::Arc::new(data::generate(
        cfg.benchmark,
        cfg.scale,
        &rt.manifest().vocab,
        cfg.data_seed,
    ));
    println!(
        "federation: {} clients, {} samples (mean {:.0}/client)",
        ds.num_clients(),
        ds.total_samples(),
        ds.total_samples() as f64 / ds.num_clients() as f64
    );

    // 3. The engine simulates hardware heterogeneity (cᵢ ~ N(1, 0.25)) and
    //    calibrates the round deadline τ so 30% of clients are stragglers.
    let engine = Engine::new(&rt, &ds, cfg.run.clone())?;
    println!(
        "deadline τ = {:.0} sim-seconds; stragglers: {:.0}%",
        engine.fleet.deadline,
        100.0 * engine.fleet.straggler_fraction()
    );

    // 4. Run. Stragglers train on k-medoids coresets instead of being
    //    dropped (FedAvg-DS) or under-trained (FedProx).
    let result = engine.run()?;
    println!("\nround  loss    acc     t/τ   coreset-clients");
    for r in &result.rounds {
        println!(
            "{:>5}  {:.4}  {:>5.1}%  {:.2}  {:>3}  (compression {:.2})",
            r.round,
            r.train_loss,
            100.0 * r.test_acc,
            r.sim_time / result.deadline,
            r.coreset_clients,
            r.mean_compression,
        );
    }
    println!(
        "\nbest accuracy {:.1}%; every round finished within τ: {}",
        100.0 * result.best_accuracy(),
        result.rounds.iter().all(|r| r.sim_time <= result.deadline * 1.001),
    );
    Ok(())
}
