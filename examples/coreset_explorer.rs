//! Coreset explorer: dissect what FedCore builds for one client.
//!
//! For a chosen benchmark client this example extracts gradient features,
//! builds the pairwise distance matrix both ways (L1 Pallas tile vs CPU
//! reference — printed max deviation), then runs all four k-medoids
//! solvers at several budgets, comparing objective cost, weight spread and
//! wall time. This is the paper's §4.2/§4.3 machinery under a magnifier.
//!
//! ```text
//! cargo run --release --example coreset_explorer -- --bench mnist
//! ```

use std::time::Instant;

use fedcore::coreset::{self, distance, Method};
use fedcore::data::{self, Benchmark};
use fedcore::fl::client::gather_features;
use fedcore::runtime::Runtime;
use fedcore::util::cli::Cli;
use fedcore::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("coreset_explorer", "inspect coreset construction for one client")
        .opt("bench", "mnist", "benchmark")
        .opt("scale", "0.1", "dataset scale")
        .opt("client", "auto", "client index, or 'auto' = largest")
        .parse();

    let rt = Runtime::load("artifacts")?;
    let bench = Benchmark::parse(args.get("bench")).expect("benchmark");
    let ds = data::generate(bench, args.get_f64("scale"), &rt.manifest().vocab, 7);
    let model = rt.manifest().model(&ds.model)?.clone();

    let client = match args.get("client") {
        "auto" => (0..ds.num_clients()).max_by_key(|&i| ds.clients[i].len()).unwrap(),
        s => s.parse().expect("client index"),
    };
    let shard = &ds.clients[client];
    let m = shard.len();
    println!("{} client {client}: m = {m} samples", bench.label());

    // Warm the model up for one local epoch first: at w₀ = 0 a linear
    // model's last-layer gradient depends only on the label, which makes
    // every same-label pair distance-0 — exactly why FedCore extracts
    // features during the round's *first training epoch* (§4.1), not at
    // the raw initial point.
    let mut params = model.init_params.clone();
    {
        let b = rt.manifest().train_batch;
        let idxs: Vec<usize> = (0..m).collect();
        for chunk in idxs.chunks(b) {
            let (x, y, w) = shard.gather_batch(chunk, None, b);
            let out = rt.train_step(&model, &params, &params, &x, &y, &w, 0.05, 0.0)?;
            params = out.params;
        }
    }

    // Gradient features (the §4.3 d̂ inputs) after the warm-up epoch.
    let t0 = Instant::now();
    let features = gather_features(&rt, &model, shard, &params)?;
    println!("feature extraction: {:.1} ms ({} × {})",
        t0.elapsed().as_secs_f64() * 1e3, m, rt.manifest().feature_dim);

    // Distance matrix: Pallas tile path vs CPU reference.
    let t0 = Instant::now();
    let tiled = distance::from_features_tiled(&rt, &features, m)?;
    let t_tiled = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    let cpu = distance::from_features_cpu(&features, m, rt.manifest().feature_dim);
    let t_cpu = t0.elapsed().as_secs_f64() * 1e3;
    let max_dev = tiled
        .d
        .iter()
        .zip(&cpu.d)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "distance matrix {m}×{m}: pallas-tiled {t_tiled:.1} ms | cpu {t_cpu:.1} ms | max |Δ| = {max_dev:.2e}"
    );

    // Solver comparison at paper-like budgets.
    println!("\n{:>6} {:<14} {:>12} {:>10} {:>10}", "b", "method", "objective", "max δ", "ms");
    for frac in [0.1, 0.25, 0.5] {
        let b = ((m as f64 * frac) as usize).max(1);
        for method in [Method::FasterPam, Method::Pam, Method::GreedyKCenter, Method::Random] {
            // PAM is O(n²k) per sweep — skip it where it would dominate
            // the demo's runtime (that gap is the point of FasterPAM).
            if method == Method::Pam && m * b > 30_000 {
                println!("{b:>6} {:<14} {:>12} {:>10} {:>10}", "PAM", "(skipped)", "-", "-");
                continue;
            }
            let mut rng = Rng::new(11);
            let t0 = Instant::now();
            let cs = coreset::select(&tiled, b, method, &mut rng);
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let max_delta = cs.deltas.iter().cloned().fold(0.0f32, f32::max);
            println!(
                "{b:>6} {:<14} {:>12.3} {:>10.0} {:>10.2}",
                method.label(),
                cs.cost,
                max_delta,
                ms
            );
            assert_eq!(cs.total_weight() as usize, m, "δ weights must sum to m");
        }
        println!();
    }
    println!("(δ weights always sum to m — every sample is represented.)");
    Ok(())
}
