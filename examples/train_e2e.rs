//! End-to-end validation driver (DESIGN.md / EXPERIMENTS.md §E2E).
//!
//! Exercises the complete three-layer stack on a real workload: generates a
//! full benchmark federation, runs all four strategies through the PJRT
//! runtime (L2 JAX models + L1 Pallas distance kernel via AOT HLO), and
//! reports loss curves, accuracies and normalized round times side by side
//! — the Table 2 / Fig. 3 experiment in one binary.
//!
//! ```text
//! cargo run --release --example train_e2e -- --bench mnist --scale 0.08 \
//!     --rounds 20 --stragglers 30
//! ```

use fedcore::config::ExperimentConfig;
use fedcore::data::{self, Benchmark};
use fedcore::fl::{all_strategies, Engine};
use fedcore::metrics::{table2_rows, RunResult};
use fedcore::runtime::Runtime;
use fedcore::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("train_e2e", "end-to-end driver: all four strategies on one benchmark")
        .opt("bench", "mnist", "mnist | shakespeare | synthetic(a,b)")
        .opt("scale", "0.08", "dataset scale (1.0 = paper)")
        .opt("rounds", "0", "rounds override (0 = preset · scale)")
        .opt("stragglers", "30", "straggler percentage")
        .opt("lr", "0", "learning-rate override")
        .opt("seed", "7", "root seed")
        .opt("workers", "1", "exec worker threads (0 = auto, 1 = sequential)")
        .opt("out", "results/e2e", "output dir for per-strategy CSVs")
        .parse();

    let bench = Benchmark::parse(args.get("bench")).expect("benchmark");
    let rt = Runtime::load("artifacts")?;
    let mut base = ExperimentConfig::scaled_preset(bench, args.get_f64("scale"));
    base.run.straggler_pct = args.get_f64("stragglers");
    base.run.seed = args.get_u64("seed");
    if args.get_usize("rounds") > 0 {
        base.run.rounds = args.get_usize("rounds");
    }
    if args.get_f64("lr") > 0.0 {
        base.run.lr = args.get_f64("lr") as f32;
    }
    base.run.workers = args.get_usize("workers");

    let ds = std::sync::Arc::new(data::generate(
        bench,
        base.scale,
        &rt.manifest().vocab,
        base.data_seed,
    ));
    let stats = data::partition::size_stats(&ds.sizes());
    println!(
        "=== {} | {} clients | {} samples (mean {:.0}, std {:.0}) | {} rounds × {} epochs | {}% stragglers ===",
        bench.label(),
        stats.clients,
        stats.total,
        stats.mean,
        stats.std,
        base.run.rounds,
        base.run.epochs,
        base.run.straggler_pct
    );

    let mut results: Vec<RunResult> = Vec::new();
    for strategy in all_strategies(base.prox_mu) {
        let cfg = base.clone().with_strategy(strategy);
        let engine = Engine::new(&rt, &ds, cfg.run.clone())?;
        let t0 = std::time::Instant::now();
        let result = engine.run()?;
        println!(
            "{:<10} wall {:>6.1}s | best acc {:>5.1}% | final loss {:.4} | mean t/τ {:.2}",
            strategy.label(),
            t0.elapsed().as_secs_f64(),
            100.0 * result.best_accuracy(),
            result.final_train_loss(),
            result.mean_normalized_round_time(),
        );
        results.push(result);
    }

    // Loss-curve table (Fig. 3 data, printed).
    println!("\nloss curves (train loss per round):");
    print!("round");
    for r in &results {
        print!("  {:>10}", r.strategy);
    }
    println!();
    let rounds = results[0].rounds.len();
    for i in 0..rounds {
        print!("{i:>5}");
        for r in &results {
            print!("  {:>10.4}", r.rounds[i].train_loss);
        }
        println!();
    }

    println!("\nTable-2 style summary:");
    for row in table2_rows(&results) {
        let mark = if row.exceeded_deadline { " ← exceeds deadline" } else { "" };
        println!(
            "{:<10} acc {:>5.1}%  mean t/τ {:>5.2}{mark}",
            row.strategy, row.accuracy_pct, row.mean_norm_time
        );
    }

    let out = args.get("out");
    std::fs::create_dir_all(out)?;
    for r in &results {
        let path = format!("{out}/{}_{}_s{}.csv", r.benchmark, r.strategy.replace('-', ""), base.run.straggler_pct);
        r.write_csv(&path)?;
    }

    // SVG figures: Fig-3-style loss curves + Fig-4-style round histogram.
    use fedcore::metrics::svg::{self, Series};
    let loss_series: Vec<Series> = results
        .iter()
        .map(|r| {
            Series::new(
                r.strategy.clone(),
                r.rounds.iter().map(|x| (x.round as f64, x.train_loss)).collect(),
            )
        })
        .collect();
    let fig3 = svg::line_chart(
        &format!("{} @ {}% stragglers — train loss", bench.label(), base.run.straggler_pct),
        "round",
        "train loss",
        &loss_series,
    );
    svg::write_svg(format!("{out}/fig3_loss.svg"), &fig3)?;

    let edges: Vec<f64> = (0..16).map(|i| i as f64 * 0.25).collect();
    let hist_series: Vec<Series> = results
        .iter()
        .map(|r| {
            let h = fedcore::metrics::Histogram::new(&r.client_times_normalized(), 0.25, 3.75);
            Series::new(
                r.strategy.clone(),
                h.edges.iter().zip(&h.counts).map(|(&e, &c)| (e, c as f64)).collect(),
            )
        })
        .collect();
    let fig4 = svg::log_histogram(
        &format!("{} @ {}% — client round times", bench.label(), base.run.straggler_pct),
        "t/τ",
        &edges,
        &hist_series,
    );
    svg::write_svg(format!("{out}/fig4_hist.svg"), &fig4)?;

    println!("\nwrote per-strategy CSVs + fig3_loss.svg + fig4_hist.svg to {out}/");
    Ok(())
}
