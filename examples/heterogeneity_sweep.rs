//! Heterogeneity sweep: the Synthetic(α, β) grid of the paper's Table 2
//! (columns Synthetic(0,0) / (0.5,0.5) / (1,1)) for all four strategies.
//!
//! Shows the paper's qualitative result: FedAvg-DS degrades as (α, β) grow
//! (dropped stragglers carry unique local distributions), while FedCore
//! holds accuracy across the whole grid.
//!
//! ```text
//! cargo run --release --example heterogeneity_sweep -- --rounds 20
//! ```

use fedcore::config::ExperimentConfig;
use fedcore::data::{self, Benchmark};
use fedcore::fl::{all_strategies, Engine};
use fedcore::runtime::Runtime;
use fedcore::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("heterogeneity_sweep", "Synthetic(α,β) grid × four strategies")
        .opt("scale", "0.2", "dataset scale")
        .opt("rounds", "16", "rounds per run")
        .opt("stragglers", "30", "straggler percentage")
        .opt("lr", "0.01", "learning rate (sweep default: faster than paper's 0.001)")
        .parse();

    let rt = Runtime::load("artifacts")?;
    let grid = [(0.0, 0.0), (0.5, 0.5), (1.0, 1.0)];

    println!(
        "{:<12} {:>16} {:>16} {:>16}",
        "strategy", "Synthetic(0,0)", "Synthetic(.5,.5)", "Synthetic(1,1)"
    );

    let mut table: Vec<(String, Vec<f64>)> = Vec::new();
    for (ai, _) in all_strategies(0.1).iter().enumerate() {
        table.push((all_strategies(0.1)[ai].label().to_string(), Vec::new()));
    }

    for &(alpha, beta) in &grid {
        let bench = Benchmark::Synthetic { alpha, beta };
        let mut base = ExperimentConfig::scaled_preset(bench, args.get_f64("scale"));
        base.run.rounds = args.get_usize("rounds");
        base.run.lr = args.get_f64("lr") as f32;
        base.run.straggler_pct = args.get_f64("stragglers");
        base.run.eval_every = 2;
        let ds = std::sync::Arc::new(data::generate(
            bench,
            base.scale,
            &rt.manifest().vocab,
            base.data_seed,
        ));
        for (si, strategy) in all_strategies(base.prox_mu).into_iter().enumerate() {
            let cfg = base.clone().with_strategy(strategy);
            let engine = Engine::new(&rt, &ds, cfg.run.clone())?;
            let r = engine.run()?;
            table[si].1.push(100.0 * r.best_accuracy());
        }
    }

    for (label, accs) in &table {
        print!("{label:<12}");
        for a in accs {
            print!(" {a:>15.1}%");
        }
        println!();
    }

    // The paper's headline qualitative checks.
    let get = |name: &str| table.iter().find(|(l, _)| l == name).unwrap().1.clone();
    let fedcore = get("FedCore");
    let ds_ = get("FedAvg-DS");
    println!();
    for (i, &(a, b)) in grid.iter().enumerate() {
        let delta = fedcore[i] - ds_[i];
        println!(
            "Synthetic({a},{b}): FedCore − FedAvg-DS = {delta:+.1} pts {}",
            if delta > 0.0 { "✓ (coresets beat dropping)" } else { "" }
        );
    }
    Ok(())
}
