//! Whole-stack profiling harness for the §Perf pass (EXPERIMENTS.md).
//!
//! Decomposes the FL hot path into its unit costs and reports where wall
//! time goes, so each optimization iteration has a before/after number:
//!
//! * L3 epoch-loop overhead: `run_epoch` (gather + literal + dispatch)
//!   vs raw artifact execution.
//! * Distance-matrix crossover: Pallas-tiled vs CPU at several m.
//! * FasterPAM init crossover: BUILD vs D² sampling.
//! * End-to-end round decomposition: train / features / distances /
//!   k-medoids / eval.
//!
//! ```text
//! cargo run --release --example perf_profile
//! ```

use std::time::Instant;

use fedcore::coreset::{self, distance, Method};
use fedcore::data::{self, Benchmark};
use fedcore::fl::client::gather_features;
use fedcore::runtime::Runtime;
use fedcore::util::rng::Rng;

fn ms(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load("artifacts")?;
    rt.warmup()?;
    let mut rng = Rng::new(5);

    // ---------- 1. L3 overhead around one train step ----------
    println!("== 1. L3 overhead: run_epoch vs raw train_step (logreg, m=256) ==");
    let ds = data::generate(Benchmark::Synthetic { alpha: 1.0, beta: 1.0 }, 0.3, &rt.manifest().vocab, 7);
    let model = rt.manifest().model("logreg")?.clone();
    let big = (0..ds.num_clients()).max_by_key(|&i| ds.clients[i].len()).unwrap();
    let shard = &ds.clients[big];
    let m = shard.len().min(256);
    let idxs: Vec<usize> = (0..m).collect();
    let b = rt.manifest().train_batch;

    // raw: reuse one gathered batch (warm the executable + caches first so
    // the first-timed loop is not paying one-time costs)
    let (x, y, w) = shard.gather_batch(&idxs[0..b], None, b);
    let mut params = model.init_params.clone();
    for _ in 0..100 {
        params = rt.train_step(&model, &params, &params, &x, &y, &w, 0.01, 0.0)?.params;
    }
    let reps = 200;
    let t0 = Instant::now();
    for _ in 0..reps {
        params = rt.train_step(&model, &params, &params, &x, &y, &w, 0.01, 0.0)?.params;
    }
    let raw_ms = ms(t0) / reps as f64;

    // full path: gather every batch (what an epoch really does)
    let t0 = Instant::now();
    let mut params2 = model.init_params.clone();
    let mut steps = 0usize;
    for _ in 0..(reps / (m / b)).max(1) {
        for chunk in idxs.chunks(b) {
            let (x, y, w) = shard.gather_batch(chunk, None, b);
            params2 = rt.train_step(&model, &params2, &params2, &x, &y, &w, 0.01, 0.0)?.params;
            steps += 1;
        }
    }
    let full_ms = ms(t0) / steps as f64;
    println!("raw step     {raw_ms:.3} ms");
    println!("epoch path   {full_ms:.3} ms  (overhead {:+.1}%)", 100.0 * (full_ms / raw_ms - 1.0));

    // ---------- 2. distance-matrix crossover ----------
    println!("\n== 2. distance matrix: Pallas-tiled vs CPU ==");
    let dim = rt.manifest().feature_dim;
    println!("{:>6} {:>12} {:>12} {:>8}", "m", "tiled (ms)", "cpu (ms)", "winner");
    for m in [128usize, 256, 512, 1024, 2048] {
        let f: Vec<f32> = (0..m * dim).map(|_| rng.normal() as f32).collect();
        let t0 = Instant::now();
        let dt = distance::from_features_tiled(&rt, &f, m)?;
        let tiled_ms = ms(t0);
        let t0 = Instant::now();
        let dc = distance::from_features_cpu(&f, m, dim);
        let cpu_ms = ms(t0);
        let dev = dt.d.iter().zip(&dc.d).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        println!(
            "{m:>6} {tiled_ms:>12.1} {cpu_ms:>12.1} {:>8}   (max|Δ| {dev:.1e})",
            if tiled_ms < cpu_ms { "tiled" } else { "cpu" }
        );
    }

    // ---------- 3. FasterPAM init crossover ----------
    println!("\n== 3. FasterPAM init: BUILD vs D² sampling (k = m/10) ==");
    println!("{:>6} {:>12} {:>12} {:>14} {:>14}", "m", "build (ms)", "d2 (ms)", "cost(build)", "cost(d2)");
    for m in [128usize, 256, 512, 1024] {
        let f: Vec<f32> = (0..m * dim).map(|_| rng.normal() as f32).collect();
        let dist = distance::from_features_cpu(&f, m, dim);
        let k = m / 10;
        let t0 = Instant::now();
        let mb = coreset::fasterpam::solve_with_init(&dist, k, &mut rng, true);
        let build_ms = ms(t0);
        let t0 = Instant::now();
        let md = coreset::fasterpam::solve_with_init(&dist, k, &mut rng, false);
        let d2_ms = ms(t0);
        println!(
            "{m:>6} {build_ms:>12.1} {d2_ms:>12.1} {:>14.3} {:>14.3}",
            coreset::objective(&dist, &mb),
            coreset::objective(&dist, &md)
        );
    }

    // ---------- 4. round decomposition (FedCore straggler client) ----------
    println!("\n== 4. FedCore straggler round decomposition (m = {}) ==", shard.len());
    let m = shard.len();
    let budget = (m / 5).max(1);
    let t_train = {
        let t0 = Instant::now();
        let mut p = model.init_params.clone();
        let all: Vec<usize> = (0..m).collect();
        for chunk in all.chunks(b) {
            let (x, y, w) = shard.gather_batch(chunk, None, b);
            p = rt.train_step(&model, &p, &p, &x, &y, &w, 0.01, 0.0)?.params;
        }
        ms(t0)
    };
    let t0 = Instant::now();
    let feats = gather_features(&rt, &model, shard, &model.init_params)?;
    let t_feat = ms(t0);
    let t0 = Instant::now();
    let dist_cpu = fedcore::fl::client::build_dist(&rt, &feats, m)?; // production dispatch
    let t_dist = ms(t0);
    let t0 = Instant::now();
    let _cs = coreset::select(&dist_cpu, budget, Method::FasterPam, &mut rng);
    let t_kmed = ms(t0);
    let total = t_train + t_feat + t_dist + t_kmed;
    println!("full-set epoch   {t_train:>8.1} ms  ({:>4.1}%)", 100.0 * t_train / total);
    println!("grad features    {t_feat:>8.1} ms  ({:>4.1}%)", 100.0 * t_feat / total);
    println!("distance matrix  {t_dist:>8.1} ms  ({:>4.1}%)", 100.0 * t_dist / total);
    println!("FasterPAM        {t_kmed:>8.1} ms  ({:>4.1}%)", 100.0 * t_kmed / total);
    println!("coreset overhead vs one epoch: {:+.1}%", 100.0 * (t_feat + t_dist + t_kmed) / t_train);

    let stats = rt.stats();
    println!(
        "\nruntime: {} execs, mean {:.2} ms/exec",
        stats.executions,
        stats.exec_nanos as f64 / stats.executions.max(1) as f64 / 1e6
    );
    println!("\n== 5. per-artifact breakdown (this process) ==");
    print!("{}", rt.artifact_stats().report());
    Ok(())
}
