//! Offline evaluation of a trained checkpoint: load a model saved by
//! `fedcore run --save-ckpt`, evaluate it on a freshly generated test set,
//! and report global + per-client accuracy (the per-client distribution is
//! where FedAvg-DS's dropped-straggler bias shows up as a long low tail).
//!
//! ```text
//! ./target/release/fedcore run --bench 'synthetic(1,1)' --strategy fedcore \
//!     --scale 0.2 --rounds 15 --save-ckpt results/fedcore.ckpt --quiet
//! cargo run --release --example evaluate_ckpt -- --ckpt results/fedcore.ckpt \
//!     --bench 'synthetic(1,1)' --scale 0.2
//! ```

use fedcore::data::{self, Benchmark};
use fedcore::fl::Checkpoint;
use fedcore::runtime::{EvalOutput, Runtime};
use fedcore::util::cli::Cli;
use fedcore::util::stats;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("evaluate_ckpt", "evaluate a saved global model, per-client breakdown")
        .req("ckpt", "checkpoint path (from fedcore run --save-ckpt)")
        .opt("bench", "synthetic(1,1)", "benchmark the model was trained on")
        .opt("scale", "0.2", "dataset scale")
        .opt("seed", "7", "data generation seed (must match training)")
        .parse();

    let rt = Runtime::load("artifacts")?;
    let ck = Checkpoint::load(args.get("ckpt"))?;
    let bench = Benchmark::parse(args.get("bench")).expect("benchmark");
    anyhow::ensure!(
        ck.model == bench.model_key(),
        "checkpoint is for '{}', benchmark '{}' needs '{}'",
        ck.model,
        bench.label(),
        bench.model_key()
    );
    let model = rt.manifest().model(&ck.model)?.clone();
    let ds = data::generate(bench, args.get_f64("scale"), &rt.manifest().vocab, args.get_u64("seed"));
    println!(
        "checkpoint: model {} | {} params | saved after round {}",
        ck.model,
        ck.params.len(),
        ck.round
    );

    // Global test set.
    let eval_shard = |shard: &data::Shard| -> anyhow::Result<EvalOutput> {
        let f = rt.manifest().feat_batch;
        let n = shard.len();
        let idxs: Vec<usize> = (0..n).collect();
        let mut total = EvalOutput::default();
        for chunk in idxs.chunks(f) {
            let (x, y, mask) = shard.gather_batch(chunk, None, f);
            total.merge(rt.evaluate(&model, &ck.params, &x, &y, &mask)?);
        }
        Ok(total)
    };
    let global = eval_shard(&ds.test)?;
    println!(
        "global test: acc {:.2}% | loss {:.4} ({} samples)",
        100.0 * global.accuracy(),
        global.mean_loss(),
        ds.test.len()
    );

    // Per-client accuracy over each client's local training shard — the
    // fairness lens: a model trained by dropping stragglers under-serves
    // the clients it dropped.
    let mut accs: Vec<f64> = Vec::with_capacity(ds.num_clients());
    for c in &ds.clients {
        accs.push(eval_shard(c)?.accuracy());
    }
    let mut sorted = accs.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!("\nper-client accuracy over {} clients:", accs.len());
    println!("  mean {:.2}%  std {:.2}%", 100.0 * stats::mean(&accs), 100.0 * stats::std_dev(&accs));
    println!(
        "  p10 {:.2}%  p50 {:.2}%  p90 {:.2}%  worst {:.2}%",
        100.0 * stats::percentile(&accs, 10.0),
        100.0 * stats::percentile(&accs, 50.0),
        100.0 * stats::percentile(&accs, 90.0),
        100.0 * sorted.first().copied().unwrap_or(0.0)
    );
    let bar = |a: f64| "#".repeat((a * 40.0) as usize);
    for (i, &a) in sorted.iter().enumerate().take(8) {
        println!("  worst[{i}] {:>6.1}% |{}", 100.0 * a, bar(a));
    }
    Ok(())
}
