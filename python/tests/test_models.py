"""L2 model correctness: shapes, gradients, train-step/feat/eval semantics."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.model import (
    ALL_MODELS,
    FEATURE_DIM,
    example_args,
    make_evaluate,
    make_grad_features,
    make_train_step,
)
from compile.models import logreg, mnist_cnn, shake_lstm
from compile.models.base import (
    ParamSpec,
    flatten,
    grad_feature,
    init_flat,
    softmax_xent,
    total_size,
    unflatten,
)

RNG = np.random.default_rng(11)


def _params(model, seed=0):
    return init_flat(model.SPECS, jax.random.PRNGKey(seed), model.INIT_SCALES)


def _batch(model, n, seed=1):
    rng = np.random.default_rng(seed)
    if model.X_DTYPE == "i32":
        x = jnp.asarray(rng.integers(0, model.NUM_CLASSES, (n,) + model.X_SHAPE), jnp.int32)
        y = jnp.asarray(rng.integers(0, model.NUM_CLASSES, (n, model.SEQ_LEN)), jnp.int32)
    else:
        x = jnp.asarray(rng.standard_normal((n,) + model.X_SHAPE), jnp.float32)
        y = jnp.asarray(rng.integers(0, model.NUM_CLASSES, n), jnp.int32)
    return x, y


class TestFlattenRoundtrip:
    @pytest.mark.parametrize("model", list(ALL_MODELS.values()), ids=list(ALL_MODELS))
    def test_unflatten_flatten_roundtrip(self, model):
        flat = jnp.asarray(RNG.standard_normal(model.PARAM_SIZE), jnp.float32)
        back = flatten(unflatten(flat, model.SPECS), model.SPECS)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(flat))

    def test_total_size(self):
        specs = (ParamSpec("a", (2, 3)), ParamSpec("b", (4,)))
        assert total_size(specs) == 10

    def test_param_sizes_match_paper_scale(self):
        assert logreg.PARAM_SIZE == 60 * 10 + 10
        assert mnist_cnn.PARAM_SIZE > 5_000
        assert shake_lstm.PARAM_SIZE > 20_000


class TestApply:
    @pytest.mark.parametrize("model", list(ALL_MODELS.values()), ids=list(ALL_MODELS))
    def test_logits_shape(self, model):
        x, y = _batch(model, 4)
        logits = model.apply(_params(model), x)
        if model.X_DTYPE == "i32":
            assert logits.shape == (4, model.SEQ_LEN, model.NUM_CLASSES)
        else:
            assert logits.shape == (4, model.NUM_CLASSES)

    def test_logreg_is_linear(self):
        p = jnp.asarray(RNG.standard_normal(logreg.PARAM_SIZE), jnp.float32)
        x1, _ = _batch(logreg, 3, seed=2)
        x2, _ = _batch(logreg, 3, seed=3)
        lhs = logreg.apply(p, x1 + x2)
        rhs = logreg.apply(p, x1) + logreg.apply(p, x2) - logreg.apply(p, jnp.zeros_like(x1))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)

    def test_logreg_finite_difference_gradient(self):
        """Strongly-convex case: autodiff grad vs central differences."""
        x, y = _batch(logreg, 8)
        p = jnp.asarray(RNG.standard_normal(logreg.PARAM_SIZE) * 0.1, jnp.float32)

        def loss(q):
            return jnp.mean(softmax_xent(logreg.apply(q, x), y))

        g = np.asarray(jax.grad(loss)(p))
        eps = 1e-3
        for idx in RNG.choice(logreg.PARAM_SIZE, 12, replace=False):
            e = np.zeros(logreg.PARAM_SIZE, np.float32)
            e[idx] = eps
            fd = (float(loss(p + e)) - float(loss(p - e))) / (2 * eps)
            assert abs(fd - g[idx]) < 5e-3, (idx, fd, g[idx])

    def test_mnist_translation_sensitivity(self):
        """CNN is not constant: distinct inputs give distinct logits."""
        p = _params(mnist_cnn, seed=4)
        x, _ = _batch(mnist_cnn, 2, seed=5)
        logits = mnist_cnn.apply(p, x)
        assert float(jnp.max(jnp.abs(logits[0] - logits[1]))) > 1e-4

    def test_lstm_causality(self):
        """Changing token t must not affect logits at positions < t."""
        p = _params(shake_lstm, seed=6)
        x, _ = _batch(shake_lstm, 1, seed=7)
        logits_a = shake_lstm.apply(p, x)
        x2 = x.at[0, 10].set((x[0, 10] + 1) % shake_lstm.NUM_CLASSES)
        logits_b = shake_lstm.apply(p, x2)
        np.testing.assert_allclose(logits_a[0, :10], logits_b[0, :10], atol=1e-5)
        assert float(jnp.max(jnp.abs(logits_a[0, 10:] - logits_b[0, 10:]))) > 1e-7


class TestTrainStep:
    @pytest.mark.parametrize("model", list(ALL_MODELS.values()), ids=list(ALL_MODELS))
    def test_step_reduces_loss_on_fixed_batch(self, model):
        step = jax.jit(make_train_step(model))
        p = _params(model)
        x, y = _batch(model, 8)
        w = jnp.ones(8, jnp.float32)
        lr, mu = jnp.float32(0.1), jnp.float32(0.0)
        _, loss0 = step(p, p, x, y, w, lr, mu)
        for _ in range(20):
            p, loss = step(p, p, x, y, w, lr, mu)
        assert float(loss) < float(loss0)

    def test_zero_weight_rows_are_ignored(self):
        """Padding semantics: a δ=0 row must not influence the step."""
        model = logreg
        step = make_train_step(model)
        p = _params(model)
        x, y = _batch(model, 8)
        w_full = jnp.asarray([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
        p1, _ = step(p, p, x, y, w_full, jnp.float32(0.5), jnp.float32(0.0))
        x_junk = x.at[4:].set(999.0)
        p2, _ = step(p, p, x_junk, y, w_full, jnp.float32(0.5), jnp.float32(0.0))
        np.testing.assert_allclose(p1, p2, atol=1e-6)

    def test_coreset_weights_reweight_gradient(self):
        """δ-weighted batch equals duplicating samples δ times (normalized)."""
        model = logreg
        step = make_train_step(model)
        p = jnp.asarray(RNG.standard_normal(model.PARAM_SIZE) * 0.1, jnp.float32)
        x, y = _batch(model, 8)
        # weight sample 0 three times, mask the rest except 1
        w = jnp.asarray([3, 1, 0, 0, 0, 0, 0, 0], jnp.float32)
        p_w, _ = step(p, p, x, y, w, jnp.float32(0.2), jnp.float32(0.0))
        x_dup = jnp.stack([x[0], x[0], x[0], x[1], x[0], x[0], x[0], x[1]])
        y_dup = jnp.stack([y[0], y[0], y[0], y[1], y[0], y[0], y[0], y[1]])
        p_d, _ = step(p, p, x_dup, y_dup, jnp.ones(8, jnp.float32), jnp.float32(0.2), jnp.float32(0.0))
        np.testing.assert_allclose(p_w, p_d, rtol=1e-4, atol=1e-5)

    def test_prox_term_pulls_toward_global(self):
        """With huge μ the step must move params toward gparams."""
        model = logreg
        step = make_train_step(model)
        p = jnp.ones(model.PARAM_SIZE, jnp.float32)
        g = jnp.zeros(model.PARAM_SIZE, jnp.float32)
        x, y = _batch(model, 8)
        w = jnp.ones(8, jnp.float32)
        # keep lr*mu < 1 so the prox pull contracts rather than overshoots
        p1, _ = step(p, g, x, y, w, jnp.float32(0.1), jnp.float32(5.0))
        assert float(jnp.linalg.norm(p1)) < float(jnp.linalg.norm(p))

    def test_prox_gradient_exact(self):
        """μ>0 adds exactly μ(p - g) to the gradient."""
        model = logreg
        step = make_train_step(model)
        x, y = _batch(model, 8)
        w = jnp.ones(8, jnp.float32)
        p = jnp.asarray(RNG.standard_normal(model.PARAM_SIZE) * 0.1, jnp.float32)
        g = jnp.asarray(RNG.standard_normal(model.PARAM_SIZE) * 0.1, jnp.float32)
        lr = jnp.float32(1.0)
        p_nomu, _ = step(p, g, x, y, w, lr, jnp.float32(0.0))
        p_mu, _ = step(p, g, x, y, w, lr, jnp.float32(0.7))
        np.testing.assert_allclose(
            np.asarray(p_nomu - p_mu), 0.7 * np.asarray(p - g), rtol=1e-4, atol=1e-5
        )

    @settings(max_examples=10, deadline=None)
    @given(lr=st.floats(1e-4, 0.5), seed=st.integers(0, 1000))
    def test_hypothesis_step_is_descent_direction_logreg(self, lr, seed):
        model = logreg
        step = make_train_step(model)
        x, y = _batch(model, 8, seed=seed)
        p = jnp.asarray(np.random.default_rng(seed).standard_normal(model.PARAM_SIZE) * 0.2, jnp.float32)
        w = jnp.ones(8, jnp.float32)
        p1, l0 = step(p, p, x, y, w, jnp.float32(lr), jnp.float32(0.0))
        _, l1 = step(p1, p1, x, y, w, jnp.float32(0.0), jnp.float32(0.0))
        # convex + small lr: loss non-increasing
        assert float(l1) <= float(l0) + 1e-6


class TestGradFeatures:
    @pytest.mark.parametrize("model", list(ALL_MODELS.values()), ids=list(ALL_MODELS))
    def test_shape_and_padding(self, model):
        feat_fn = make_grad_features(model)
        x, y = _batch(model, 16)
        f, ce = feat_fn(_params(model), x, y)
        assert f.shape == (16, FEATURE_DIM)
        assert ce.shape == (16,)
        # columns beyond the model's class count are zero padding
        np.testing.assert_array_equal(
            np.asarray(f[:, model.NUM_CLASSES :]), 0.0
        )

    def test_logreg_feature_is_exact_lastlayer_grad(self):
        x, y = _batch(logreg, 8)
        p = jnp.asarray(RNG.standard_normal(logreg.PARAM_SIZE) * 0.1, jnp.float32)
        f, _ = make_grad_features(logreg)(p, x, y)
        expected = grad_feature(logreg.apply(p, x), y)
        np.testing.assert_allclose(f[:, :10], expected, rtol=1e-5, atol=1e-6)

    def test_feature_distance_bounds_for_identical_samples(self):
        """Identical samples must have identical features (distance 0)."""
        x, y = _batch(logreg, 8)
        x = x.at[1].set(x[0])
        y = y.at[1].set(y[0])
        f, _ = make_grad_features(logreg)(_params(logreg), x, y)
        np.testing.assert_allclose(f[0], f[1], atol=1e-6)


class TestEvaluate:
    @pytest.mark.parametrize("model", list(ALL_MODELS.values()), ids=list(ALL_MODELS))
    def test_mask_zeroes_rows(self, model):
        ev = make_evaluate(model)
        x, y = _batch(model, 8)
        p = _params(model)
        m_half = jnp.asarray([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
        l_half, c_half, n_half = ev(p, x, y, m_half)
        l_full, c_full, n_full = ev(p, x, y, jnp.ones(8, jnp.float32))
        assert float(n_half) == 4.0 and float(n_full) == 8.0
        assert float(l_half) <= float(l_full) + 1e-5

    def test_perfect_predictions_counted(self):
        # craft logreg params that trivially classify y = argmax(x[:10])
        x = jnp.eye(10, 60, dtype=jnp.float32) * 10.0
        y = jnp.arange(10, dtype=jnp.int32)
        w = np.zeros((60, 10), np.float32)
        w[:10, :10] = np.eye(10)
        p = jnp.asarray(np.concatenate([w.reshape(-1), np.zeros(10, np.float32)]))
        _, correct, n = make_evaluate(logreg)(p, x, y, jnp.ones(10, jnp.float32))
        assert float(correct) == 10.0 and float(n) == 10.0


class TestExampleArgs:
    @pytest.mark.parametrize("model", list(ALL_MODELS.values()), ids=list(ALL_MODELS))
    @pytest.mark.parametrize("fn", ["train", "feat", "eval"])
    def test_traceable(self, model, fn):
        from compile.model import FN_FACTORIES

        jax.eval_shape(FN_FACTORIES[fn](model), *example_args(model, fn, 8))
