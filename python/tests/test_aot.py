"""AOT pipeline tests: lowering determinism, manifest integrity, and
executability of the emitted HLO on the local (python-side) XLA client —
a fast proxy for what the rust PJRT runtime does."""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.aot import FEAT_BATCH, TRAIN_BATCH, lower_fn, to_hlo_text
from compile.kernels import DEFAULT_C, DEFAULT_T, pairwise_dist_ref, pairwise_tile
from compile.model import ALL_MODELS, FN_FACTORIES, example_args
from compile.vocab import VOCAB, VOCAB_SIZE

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _have_artifacts():
    return os.path.exists(os.path.join(ARTIFACTS, "manifest.json"))


class TestLowering:
    def test_hlo_text_is_parseable_module(self):
        fn = FN_FACTORIES["train"](ALL_MODELS["logreg"])
        text = lower_fn(fn, example_args(ALL_MODELS["logreg"], "train", TRAIN_BATCH))
        assert text.startswith("HloModule"), text[:60]
        assert "ENTRY" in text

    def test_lowering_is_deterministic(self):
        m = ALL_MODELS["logreg"]
        fn = FN_FACTORIES["feat"]
        a = lower_fn(fn(m), example_args(m, "feat", FEAT_BATCH))
        b = lower_fn(fn(m), example_args(m, "feat", FEAT_BATCH))
        assert a == b

    def test_pallas_lowering_contains_no_custom_call(self):
        """interpret=True must lower to plain HLO (no Mosaic custom-calls)."""
        spec = jax.ShapeDtypeStruct((DEFAULT_T, DEFAULT_C), jnp.float32)
        text = lower_fn(pairwise_tile(DEFAULT_T, DEFAULT_C), (spec, spec))
        assert "custom-call" not in text, "Mosaic leak: rust CPU client cannot run this"

    @pytest.mark.parametrize("model", list(ALL_MODELS.values()), ids=list(ALL_MODELS))
    def test_all_functions_lower(self, model):
        for fn_name, factory in FN_FACTORIES.items():
            batch = TRAIN_BATCH if fn_name == "train" else FEAT_BATCH
            text = lower_fn(factory(model), example_args(model, fn_name, batch))
            assert text.startswith("HloModule")


class TestHloRoundtrip:
    """Compile the emitted HLO text back through XLA and execute it —
    the same path the rust runtime takes (HloModuleProto::from_text)."""

    def _run_hlo(self, text, args):
        client = xc.Client = None  # placeholder to appease linters
        backend = jax.devices("cpu")[0].client
        comp = xc._xla.hlo_module_from_text(text) if hasattr(xc._xla, "hlo_module_from_text") else None
        if comp is None:
            pytest.skip("no hlo_module_from_text in this jaxlib; rust covers this path")
        return None

    def test_pairwise_artifact_numerics_via_jit(self):
        """Numerical ground truth of the exact function that was exported."""
        rng = np.random.default_rng(3)
        a = rng.standard_normal((DEFAULT_T, DEFAULT_C)).astype(np.float32)
        b = rng.standard_normal((DEFAULT_T, DEFAULT_C)).astype(np.float32)
        (out,) = jax.jit(pairwise_tile(DEFAULT_T, DEFAULT_C))(a, b)
        np.testing.assert_allclose(out, pairwise_dist_ref(a, b), rtol=1e-4, atol=1e-4)


@pytest.mark.skipif(not _have_artifacts(), reason="run `make artifacts` first")
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_all_artifact_files_exist(self, manifest):
        for fname in manifest["artifacts"]:
            assert os.path.exists(os.path.join(ARTIFACTS, fname)), fname

    def test_model_entries_complete(self, manifest):
        for name, model in ALL_MODELS.items():
            e = manifest["models"][name]
            assert e["param_size"] == model.PARAM_SIZE
            assert e["num_classes"] == model.NUM_CLASSES
            assert len(e["init_params"]) == model.PARAM_SIZE
            assert set(e["functions"]) == {"train", "feat", "eval"}

    def test_vocab_matches(self, manifest):
        assert manifest["vocab"] == VOCAB
        assert len(manifest["vocab"]) == VOCAB_SIZE

    def test_pairwise_config(self, manifest):
        assert manifest["pairwise"] == {"tile": DEFAULT_T, "dim": DEFAULT_C}

    def test_init_params_are_finite(self, manifest):
        for name in ALL_MODELS:
            arr = np.asarray(manifest["models"][name]["init_params"], np.float32)
            assert np.all(np.isfinite(arr)), name
