"""HLO audit of the AOT artifacts — the L2 performance/portability checks
from DESIGN.md §6: every artifact must be CPU-executable (no custom-calls),
loops must stay rolled (scan -> while, not 20x unrolled LSTM cells), and
module sizes must stay in the regime the rust runtime compiles in
milliseconds. Skips when artifacts/ has not been built."""

from __future__ import annotations

import os
import re

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

ARTIFACTS = [
    "logreg_train.hlo.txt",
    "logreg_feat.hlo.txt",
    "logreg_eval.hlo.txt",
    "mnist_train.hlo.txt",
    "mnist_feat.hlo.txt",
    "mnist_eval.hlo.txt",
    "shake_train.hlo.txt",
    "shake_feat.hlo.txt",
    "shake_eval.hlo.txt",
    "pairwise_dist.hlo.txt",
]


def read(name: str) -> str:
    path = os.path.join(ART, name)
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return f.read()


@pytest.mark.parametrize("name", ARTIFACTS)
class TestEveryArtifact:
    def test_is_an_hlo_module(self, name):
        text = read(name)
        assert text.startswith("HloModule"), f"{name} missing HloModule header"
        assert "ROOT" in text

    def test_no_custom_calls(self, name):
        # A Mosaic/TPU custom-call would make the artifact non-executable on
        # the CPU PJRT client (the aot_recipe gotcha).
        text = read(name)
        assert "custom-call" not in text, f"{name} contains a custom-call"

    def test_no_float64(self, name):
        # The runtime moves f32/s32 literals only; f64 would mean jax
        # x64 mode leaked into the lowering.
        text = read(name)
        assert " f64[" not in text, f"{name} contains f64 values"

    def test_instruction_count_in_compile_friendly_regime(self, name):
        # Catches accidental unrolling (e.g. the LSTM scan exploding into
        # 20 copies of the cell): the biggest module (shake_train bwd) sits
        # around 1.5k instructions; 8k is far beyond anything intended.
        text = read(name)
        instructions = len(re.findall(r"^\s+\S+ = ", text, re.MULTILINE))
        assert 3 <= instructions < 8000, f"{name}: {instructions} instructions"


class TestStructure:
    def test_lstm_scan_stays_rolled(self):
        # jax.lax.scan lowers to a while loop; an unrolled LSTM would have
        # no while op and ~20x the instructions (the kept scan-vs-unroll
        # decision in EXPERIMENTS.md SPerf iteration 4).
        text = read("shake_train.hlo.txt")
        assert "while(" in text or "while (" in text.lower() or " while" in text, (
            "shake_train lost its while loop (scan unrolled?)"
        )

    def test_train_returns_params_and_loss(self):
        for model in ["logreg", "mnist", "shake"]:
            text = read(f"{model}_train.hlo.txt")
            root = [l for l in text.splitlines() if "ROOT" in l]
            assert root, model
            # tuple of (params f32[P], loss f32[])
            assert "tuple(" in root[-1] or "(f32[" in root[-1], root[-1]

    def test_pairwise_has_a_dot(self):
        # The MXU rethink: the kernel must lower to a dot (a @ b^T), not an
        # elementwise broadcast-subtract pyramid.
        text = read("pairwise_dist.hlo.txt")
        assert re.search(r"\bdot\(", text), "pairwise kernel lost its matmul"

    def test_conv_present_in_mnist(self):
        text = read("mnist_train.hlo.txt")
        assert "convolution" in text, "mnist model lost its convolutions"

    def test_parameter_counts_match_manifest(self):
        import json

        path = os.path.join(ART, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            manifest = json.load(f)
        for model, entry in manifest["models"].items():
            text = read(f"{model}_train.hlo.txt")
            p = entry["param_size"]
            assert f"f32[{p}]" in text, f"{model}: no f32[{p}] parameter in HLO"


class TestFusionQuality:
    """Coarse L2 efficiency audit: the CPU backend fuses elementwise chains;
    a pathological lowering shows up as an instruction-count blowup relative
    to the model's parameter count, not as a micro-metric."""

    def test_logreg_modules_are_small(self):
        # Linear model: train fwd+bwd should be on the order of dozens of
        # ops, not hundreds.
        text = read("logreg_train.hlo.txt")
        instructions = len(re.findall(r"^\s+\S+ = ", text, re.MULTILINE))
        assert instructions < 400, f"logreg_train has {instructions} instructions"

    def test_feat_cheaper_than_train(self):
        # grad_features is forward + last-layer gradient only — it must not
        # drag the full backward pass along (the SS4.3 'almost as cheap as
        # the loss' property).
        for model in ["logreg", "mnist", "shake"]:
            train = len(re.findall(r"^\s+\S+ = ", read(f"{model}_train.hlo.txt"), re.MULTILINE))
            feat = len(re.findall(r"^\s+\S+ = ", read(f"{model}_feat.hlo.txt"), re.MULTILINE))
            assert feat <= train, f"{model}: feat ({feat}) heavier than train ({train})"
