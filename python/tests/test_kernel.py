"""L1 kernel correctness: Pallas pairwise-distance vs the pure-jnp oracle.

This is the CORE correctness signal for the compute hot-spot: the distances
that drive k-medoids coreset selection must match the naive broadcast
reference to float tolerance, across shapes, dtypes and data regimes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import (
    DEFAULT_C,
    DEFAULT_T,
    grad_feature_ref,
    pairwise_dist_ref,
    pairwise_full,
    pairwise_tile,
)

RNG = np.random.default_rng(7)


def _rand(shape, scale=1.0, dtype=np.float32):
    return (RNG.standard_normal(shape) * scale).astype(dtype)


class TestPairwiseTile:
    def test_default_tile_matches_ref(self):
        a = _rand((DEFAULT_T, DEFAULT_C))
        b = _rand((DEFAULT_T, DEFAULT_C))
        (out,) = pairwise_tile(DEFAULT_T, DEFAULT_C)(a, b)
        np.testing.assert_allclose(out, pairwise_dist_ref(a, b), rtol=1e-5, atol=1e-5)

    def test_self_distance_diagonal_zero(self):
        a = _rand((32, 16))
        (out,) = pairwise_tile(32, 16)(a, a)
        np.testing.assert_allclose(np.diag(out), np.zeros(32), atol=2e-3)

    def test_symmetry_on_self(self):
        a = _rand((64, 8))
        (out,) = pairwise_tile(64, 8)(a, a)
        np.testing.assert_allclose(out, np.asarray(out).T, rtol=1e-4, atol=1e-4)

    def test_zero_inputs(self):
        z = np.zeros((16, 8), np.float32)
        (out,) = pairwise_tile(16, 8)(z, z)
        np.testing.assert_array_equal(np.asarray(out), np.zeros((16, 16)))

    def test_known_values(self):
        # d([0,0],[3,4]) = 5 etc.
        a = np.array([[0.0, 0.0], [1.0, 0.0]], np.float32)
        b = np.array([[3.0, 4.0], [0.0, 0.0]], np.float32)
        (out,) = pairwise_tile(2, 2)(a, b)
        np.testing.assert_allclose(out, [[5.0, 0.0], [np.sqrt(20.0), 1.0]], rtol=1e-6)

    def test_zero_pad_columns_do_not_change_distance(self):
        """The artifact pads feature dim to C=64; padding must be inert."""
        a = _rand((32, 10))
        b = _rand((32, 10))
        ap = np.zeros((32, 64), np.float32)
        bp = np.zeros((32, 64), np.float32)
        ap[:, :10], bp[:, :10] = a, b
        (out,) = pairwise_tile(32, 64)(ap, bp)
        np.testing.assert_allclose(out, pairwise_dist_ref(a, b), rtol=1e-5, atol=1e-5)

    def test_large_magnitude_stability(self):
        # The MXU-friendly ||a||^2+||b||^2-2ab expansion loses ~sqrt(eps)*scale
        # of absolute precision on near-zero distances (cancellation); that is
        # inherent to the formulation, and harmless for k-medoids, which only
        # ranks distances. Tolerance is therefore scale-aware.
        scale = 1e3
        a = _rand((16, 8), scale=scale)
        (out,) = pairwise_tile(16, 8)(a, a)
        ref = pairwise_dist_ref(a, a)
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=5e-3 * scale)

    def test_tiny_magnitude_stability(self):
        a = _rand((16, 8), scale=1e-4)
        (out,) = pairwise_tile(16, 8)(a, a)
        np.testing.assert_allclose(out, pairwise_dist_ref(a, a), rtol=1e-3, atol=2e-7)

    @settings(max_examples=25, deadline=None)
    @given(
        t=st.sampled_from([8, 16, 32, 128]),
        c=st.sampled_from([4, 8, 10, 64]),
        scale=st.floats(0.01, 10.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, t, c, scale, seed):
        rng = np.random.default_rng(seed)
        a = (rng.standard_normal((t, c)) * scale).astype(np.float32)
        b = (rng.standard_normal((t, c)) * scale).astype(np.float32)
        (out,) = pairwise_tile(t, c)(a, b)
        ref = pairwise_dist_ref(a, b)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4 * max(scale, 1.0))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_bf16_inputs_upcast(self, seed):
        """Kernel accepts bf16 inputs (TPU-native) and accumulates in f32."""
        rng = np.random.default_rng(seed)
        a32 = rng.standard_normal((32, 16)).astype(np.float32)
        a16 = jnp.asarray(a32, jnp.bfloat16)
        (out,) = pairwise_tile(32, 16)(a16, a16)
        ref = pairwise_dist_ref(np.asarray(a16, np.float32), np.asarray(a16, np.float32))
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-2, atol=1e-2)


class TestPairwiseFull:
    def test_gridded_matches_ref(self):
        n, t, c = 256, 128, 64
        a = _rand((n, c))
        (out,) = pairwise_full(n, t, c)(a, a)
        # atol covers the expansion's cancellation residue on the diagonal
        # (self-distances), ~sqrt(eps * C).
        np.testing.assert_allclose(out, pairwise_dist_ref(a, a), rtol=1e-4, atol=1e-2)

    def test_gridded_matches_tilewise_assembly(self):
        """The rust driver assembles the matrix tile-by-tile; both paths agree."""
        n, t, c = 64, 32, 8
        a = _rand((n, c))
        (full,) = pairwise_full(n, t, c)(a, a)
        tile = pairwise_tile(t, c)
        assembled = np.zeros((n, n), np.float32)
        for i in range(0, n, t):
            for j in range(0, n, t):
                (blk,) = tile(a[i : i + t], a[j : j + t])
                assembled[i : i + t, j : j + t] = np.asarray(blk)
        np.testing.assert_allclose(np.asarray(full), assembled, rtol=1e-5, atol=1e-5)

    def test_rejects_non_multiple(self):
        with pytest.raises(ValueError):
            pairwise_full(100, 32, 8)

    @settings(max_examples=8, deadline=None)
    @given(
        blocks=st.integers(1, 4),
        t=st.sampled_from([16, 32]),
        c=st.sampled_from([8, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_grid_sweep(self, blocks, t, c, seed):
        n = blocks * t
        rng = np.random.default_rng(seed)
        a = rng.standard_normal((n, c)).astype(np.float32)
        b = rng.standard_normal((n, c)).astype(np.float32)
        (out,) = pairwise_full(n, t, c)(a, b)
        np.testing.assert_allclose(out, pairwise_dist_ref(a, b), rtol=1e-4, atol=1e-4)


class TestGradFeatureRef:
    def test_matches_autodiff(self):
        """softmax(z)-onehot(y) IS d(CE)/d(logits): check against jax.grad."""
        logits = jnp.asarray(_rand((5, 10)))
        labels = jnp.asarray(RNG.integers(0, 10, size=5), jnp.int32)

        def total_ce(z):
            logz = jax.nn.logsumexp(z, axis=-1)
            gold = jnp.take_along_axis(z, labels[:, None], axis=-1)[:, 0]
            return jnp.sum(logz - gold)

        autodiff = jax.grad(total_ce)(logits)
        np.testing.assert_allclose(
            grad_feature_ref(logits, labels), autodiff, rtol=1e-5, atol=1e-6
        )

    def test_rows_sum_to_zero(self):
        logits = jnp.asarray(_rand((7, 10)))
        labels = jnp.zeros(7, jnp.int32)
        g = grad_feature_ref(logits, labels)
        np.testing.assert_allclose(jnp.sum(g, axis=-1), np.zeros(7), atol=1e-6)

    def test_norm_bounded_by_sqrt2(self):
        """||softmax - onehot|| <= sqrt(2): the d-hat features live in a ball."""
        logits = jnp.asarray(_rand((50, 10), scale=25.0))
        labels = jnp.asarray(RNG.integers(0, 10, size=50), jnp.int32)
        g = grad_feature_ref(logits, labels)
        assert float(jnp.max(jnp.linalg.norm(g, axis=-1))) <= np.sqrt(2.0) + 1e-5
