"""L2: the jax functions that become the AOT artifacts.

For every benchmark model (logreg / mnist / shake) three functions are
lowered to HLO text and executed from the rust coordinator:

* ``train_step``  — one weighted SGD step on a fixed-size batch. The per-
  sample weight vector ``w`` carries (a) the coreset weights δ* from the
  k-medoids assignment (paper Eq. 5), (b) plain 1s for full-set epochs,
  and (c) 0s for padding in the ragged last batch. A ``mu > 0`` scalar adds
  the FedProx proximal term μ/2‖p − p_global‖² so the same artifact serves
  the FedProx baseline.
* ``grad_features`` — per-sample last-layer gradients softmax(z)−onehot(y)
  (paper §4.3's d̂ approximation), zero-padded to the shared feature width
  C=64, plus per-sample losses. The coordinator collects these during the
  round's first full-set epoch, then feeds them to the L1 pairwise-distance
  kernel and FasterPAM.
* ``evaluate`` — masked sum-loss and correct-count for test metrics.

All functions take/return the model parameters as ONE flat f32[P] vector
(see models/base.py) and return tuples, matching the rust runtime's
``to_tupleN`` unwrapping.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels import DEFAULT_C
from .models import ALL_MODELS
from .models.base import grad_feature, softmax_xent

FEATURE_DIM = DEFAULT_C  # padded feature width shared with the L1 kernel


def _per_sample_loss(model, logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Per-sample CE; sequence models average over positions -> [B]."""
    ce = softmax_xent(logits, y)
    if ce.ndim == 2:  # [B, S] sequence task
        ce = jnp.mean(ce, axis=-1)
    return ce


def make_train_step(model):
    """(params[P], gparams[P], x, y, w[B], lr[], mu[]) -> (params'[P], loss[])."""

    def train_step(params, gparams, x, y, w, lr, mu):
        def loss_fn(p):
            logits = model.apply(p, x)
            ce = _per_sample_loss(model, logits, y)  # [B]
            wsum = jnp.maximum(jnp.sum(w), 1e-8)
            data_loss = jnp.sum(w * ce) / wsum
            prox = 0.5 * mu * jnp.sum((p - gparams) ** 2)
            return data_loss + prox, data_loss

        (_, data_loss), grad = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return params - lr * grad, data_loss

    return train_step


def make_grad_features(model):
    """(params[P], x[F,…], y[F]) -> (feat[F, FEATURE_DIM], loss[F]).

    feat rows are the paper's d̂ gradient proxies; the pairwise L2 norms of
    these rows are exactly the k-medoids distances of Eq. (5). Sequence
    models average the per-position last-layer gradient over positions.
    """

    def grad_features(params, x, y):
        logits = model.apply(params, x)
        g = grad_feature(logits, y)  # [..., C_model]
        if g.ndim == 3:  # [B, S, V] -> mean over positions
            g = jnp.mean(g, axis=1)
        ce = _per_sample_loss(model, logits, y)
        pad = FEATURE_DIM - g.shape[-1]
        if pad < 0:
            raise ValueError(f"model feature dim {g.shape[-1]} > {FEATURE_DIM}")
        if pad:
            g = jnp.pad(g, ((0, 0), (0, pad)))
        return g, ce

    return grad_features


def make_evaluate(model):
    """(params[P], x[F,…], y[F], m[F]) -> (loss_sum[], correct[], weight[]).

    ``m`` masks padding rows. For sequence models ``correct`` counts the
    per-sample fraction of positions predicted right, so that global
    accuracy = Σcorrect / Σm matches next-char accuracy.
    """

    def evaluate(params, x, y, m):
        logits = model.apply(params, x)
        ce = _per_sample_loss(model, logits, y)
        pred = jnp.argmax(logits, axis=-1)
        hit = (pred == y).astype(jnp.float32)
        if hit.ndim == 2:
            hit = jnp.mean(hit, axis=-1)
        return jnp.sum(ce * m), jnp.sum(hit * m), jnp.sum(m)

    return evaluate


def example_args(model, fn: str, batch: int) -> Tuple[jnp.ndarray, ...]:
    """ShapeDtypeStructs used to trace each artifact."""
    f32, i32 = jnp.float32, jnp.int32
    p = jax.ShapeDtypeStruct((model.PARAM_SIZE,), f32)
    xdt = i32 if model.X_DTYPE == "i32" else f32
    x = jax.ShapeDtypeStruct((batch,) + model.X_SHAPE, xdt)
    if getattr(model, "SEQ_LEN", None):
        y = jax.ShapeDtypeStruct((batch, model.SEQ_LEN), i32)
    else:
        y = jax.ShapeDtypeStruct((batch,), i32)
    scalar = jax.ShapeDtypeStruct((), f32)
    vec = jax.ShapeDtypeStruct((batch,), f32)
    if fn == "train":
        return (p, p, x, y, vec, scalar, scalar)
    if fn == "feat":
        return (p, x, y)
    if fn == "eval":
        return (p, x, y, vec)
    raise ValueError(fn)


FN_FACTORIES = {
    "train": make_train_step,
    "feat": make_grad_features,
    "eval": make_evaluate,
}

__all__ = [
    "ALL_MODELS",
    "FEATURE_DIM",
    "FN_FACTORIES",
    "example_args",
    "make_evaluate",
    "make_grad_features",
    "make_train_step",
]
