"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground truth the pytest suite checks the Pallas kernels
against (assert_allclose). They are deliberately written in the most
obvious way possible — broadcasting, no tiling tricks — so a bug in the
kernel cannot be mirrored here.
"""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_dist_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Naive O(n*m*c) pairwise L2 distance: out[i, j] = ||a[i] - b[j]||."""
    diff = a[:, None, :] - b[None, :, :]
    return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))


def grad_feature_ref(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Last-layer gradient of softmax cross-entropy: softmax(z) - onehot(y).

    This is the paper's section 4.3 ``d_hat`` feature, for which the
    distance kernel computes pairwise norms.
    """
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    onehot = jnp.eye(logits.shape[-1], dtype=logits.dtype)[labels]
    return probs - onehot
