"""L1 Pallas kernel: tiled pairwise gradient-distance matrix.

FedCore's coreset hot-spot (paper section 4.3) is the m x m matrix of
last-layer gradient distances  d_hat[j, k] = || f_j - f_k ||_2  with
f in R^C the per-sample last-layer gradient (softmax(z) - onehot(y)).

TPU rethink of the paper's GPU broadcast-subtract: inside one T x T output
tile we expand  ||a - b||^2 = ||a||^2 + ||b||^2 - 2 a.b^T  so the inner
product is a (T, C) @ (C, T) matmul on the MXU systolic array, and the two
squared norms are cheap VPU row reductions. A GPU-style per-pair subtract
would never touch the MXU and would stream T*T*C elements through VMEM
instead of 2*T*C.

Two entry points:

* ``pairwise_tile(T, C)``      - single-tile kernel; the artifact exported
  for the rust coordinator, which tiles the full m x m matrix itself
  (m varies per client; HLO shapes are static).
* ``pairwise_full(N, T, C)``   - gridded version with BlockSpecs expressing
  the HBM->VMEM schedule; used by the python test-suite and as the
  documentation of the intended TPU grid.

All Pallas calls use ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the rust
runtime runs anywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU tile edge on current TPUs; also the lane count. T=128 keeps the
# (T, C) @ (C, T) product a single systolic pass per tile.
DEFAULT_T = 128
# Feature dim padded to the max over models (shake vocab = 64); multiples
# of 8 sublanes. Padding columns are zero and do not change distances.
DEFAULT_C = 64


def _dist_kernel(a_ref, b_ref, o_ref):
    """One T x T output tile of the pairwise L2 distance matrix."""
    a = a_ref[...].astype(jnp.float32)  # (T, C)
    b = b_ref[...].astype(jnp.float32)  # (T, C)
    # Row norms: VPU reductions, kept 2-D so broadcasting stays in-lane.
    an = jnp.sum(a * a, axis=1, keepdims=True)  # (T, 1)
    bn = jnp.sum(b * b, axis=1, keepdims=True)  # (T, 1)
    # MXU: a @ b^T with f32 accumulation.
    ip = jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (T, T)
    d2 = an + jnp.transpose(bn) - 2.0 * ip
    # Clamp tiny negative fp residue before the sqrt.
    o_ref[...] = jnp.sqrt(jnp.maximum(d2, 0.0))


@functools.lru_cache(maxsize=None)
def pairwise_tile(t: int = DEFAULT_T, c: int = DEFAULT_C):
    """Single (t, c) x (t, c) -> (t, t) distance tile.

    This is the exported artifact: the rust coordinator pads per-client
    feature matrices to multiples of ``t`` and fills the full m x m matrix
    tile by tile (padding rows produce garbage distances the driver never
    reads, because it knows the true m).
    """

    def fn(a, b):
        out = pl.pallas_call(
            _dist_kernel,
            out_shape=jax.ShapeDtypeStruct((t, t), jnp.float32),
            interpret=True,
        )(a, b)
        return (out,)

    return fn


@functools.lru_cache(maxsize=None)
def pairwise_full(n: int, t: int = DEFAULT_T, c: int = DEFAULT_C):
    """Gridded (n, c) -> (n, n) distance matrix, n a multiple of t.

    The BlockSpec index maps express the intended TPU HBM->VMEM schedule:
    grid position (i, j) streams row-block i of ``a`` and row-block j of
    ``b`` into VMEM and emits output block (i, j). Per-step VMEM footprint
    is 2*t*c*4 B of input + t*t*4 B of output (~96 KiB at t=128, c=64),
    far under the ~16 MiB VMEM budget, leaving room for the pipeline to
    double-buffer the next j-block while the MXU works.
    """
    if n % t != 0:
        raise ValueError(f"n={n} must be a multiple of t={t}")
    grid = (n // t, n // t)

    def fn(a, b):
        out = pl.pallas_call(
            _dist_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((t, c), lambda i, j: (i, 0)),
                pl.BlockSpec((t, c), lambda i, j: (j, 0)),
            ],
            out_specs=pl.BlockSpec((t, t), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
            interpret=True,
        )(a, b)
        return (out,)

    return fn
