"""L1: Pallas kernels for FedCore's compute hot-spot (pairwise gradient
distances feeding the k-medoids coreset selection)."""

from .pairwise import DEFAULT_C, DEFAULT_T, pairwise_full, pairwise_tile  # noqa: F401
from .ref import grad_feature_ref, pairwise_dist_ref  # noqa: F401
