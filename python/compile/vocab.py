"""Character vocabulary shared between the python compile path and the rust
data layer (via artifacts/manifest.json).

64 symbols: lowercase, uppercase folds to lowercase on the rust side before
lookup, so the table covers lowercase letters, digits-as-one-bucket is not
needed for Shakespeare, plus the punctuation that actually occurs in the
corpus. Index 0 is the unknown/pad symbol.
"""

VOCAB = "\x00 abcdefghijklmnopqrstuvwxyz.,;:!?'-\n\"()[]0123456789&_ABCDEFGHIJ"
VOCAB_SIZE = 64

assert len(VOCAB) == VOCAB_SIZE, len(VOCAB)
