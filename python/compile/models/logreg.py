"""Synthetic-benchmark model: multinomial logistic regression 60 -> 10.

Matches the FedProx synthetic benchmark (paper section 6.1, dataset 3):
x in R^60, 10 classes, trained with SGD. Strongly convex once L2-regularized,
which is the regime of the paper's Theorem 5.1; the convergence-check
example leans on this model.
"""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from .base import ParamSpec, total_size, unflatten

NAME = "logreg"
INPUT_DIM = 60
NUM_CLASSES = 10

SPECS = (
    ParamSpec("w", (INPUT_DIM, NUM_CLASSES)),
    ParamSpec("b", (NUM_CLASSES,)),
)
PARAM_SIZE = total_size(SPECS)
INIT_SCALES = {"w": 0.0, "b": 0.0}  # FedProx inits LR at zero
X_SHAPE = (INPUT_DIM,)  # per-sample input shape (batch dim prepended)
X_DTYPE = "f32"


def apply(flat_params: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """x [B, 60] -> logits [B, 10]."""
    p: Dict[str, jnp.ndarray] = unflatten(flat_params, SPECS)
    return x @ p["w"] + p["b"]
