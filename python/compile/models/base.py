"""Shared machinery for the L2 models.

Every model exposes its parameters to the rust coordinator as ONE flat
f32[P] vector. The helpers here unflatten that vector into the model's
named tensors inside the jitted function, so that:

* the rust side ships exactly one `Literal` per call for the parameters,
* FedAvg aggregation / FedProx prox distance are plain Vec<f32> math in L3,
* `jax.grad` over the flat vector is itself flat — no pytree crosses the
  HLO boundary.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ParamSpec:
    """Name + shape of one parameter tensor inside the flat vector."""

    name: str
    shape: Tuple[int, ...]

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


def total_size(specs: Sequence[ParamSpec]) -> int:
    return sum(s.size for s in specs)


def unflatten(flat: jnp.ndarray, specs: Sequence[ParamSpec]) -> Dict[str, jnp.ndarray]:
    """Slice the flat f32[P] vector into the model's named tensors."""
    out: Dict[str, jnp.ndarray] = {}
    offset = 0
    for s in specs:
        out[s.name] = jax.lax.dynamic_slice_in_dim(flat, offset, s.size).reshape(s.shape)
        offset += s.size
    return out


def flatten(params: Dict[str, jnp.ndarray], specs: Sequence[ParamSpec]) -> jnp.ndarray:
    return jnp.concatenate([params[s.name].reshape(-1) for s in specs])


def init_flat(specs: Sequence[ParamSpec], key: jax.Array, scales: Dict[str, float]) -> jnp.ndarray:
    """Gaussian init with per-tensor scale; biases (scale 0) start at zero."""
    chunks: List[jnp.ndarray] = []
    for s in specs:
        key, sub = jax.random.split(key)
        scale = scales.get(s.name, 0.0)
        if scale == 0.0:
            chunks.append(jnp.zeros(s.size, jnp.float32))
        else:
            chunks.append(scale * jax.random.normal(sub, (s.size,), jnp.float32))
    return jnp.concatenate(chunks)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-example softmax cross-entropy. logits [..., C], labels [...] i32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold


def grad_feature(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Last-layer gradient of softmax CE: softmax(z) - onehot(y) (paper 4.3)."""
    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    return probs - onehot
