"""L2 model zoo: one module per paper benchmark (section 6.1)."""

from . import logreg, mnist_cnn, shake_lstm

ALL_MODELS = {
    logreg.NAME: logreg,
    mnist_cnn.NAME: mnist_cnn,
    shake_lstm.NAME: shake_lstm,
}
