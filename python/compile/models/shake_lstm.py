"""Shakespeare-benchmark model: character-level LSTM for next-char prediction.

Mirrors the paper's LSTM on the Complete Works of Shakespeare (section 6.1,
dataset 2): embed -> single LSTM layer (lax.scan over the sequence) ->
dense head over the character vocabulary. The loss/feature/accuracy are
averaged over sequence positions, so one (sequence, shifted-sequence) pair
is one "sample" for coreset purposes — matching how the LEAF/FedProx
Shakespeare task counts samples.

The vocabulary (64 symbols) is shared with the rust data layer via the
artifact manifest; see ``python/compile/vocab.py``.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .base import ParamSpec, total_size, unflatten
from ..vocab import VOCAB_SIZE

NAME = "shake"
SEQ_LEN = 20
EMBED = 32
HIDDEN = 64
NUM_CLASSES = VOCAB_SIZE  # 64

SPECS = (
    ParamSpec("embed", (VOCAB_SIZE, EMBED)),
    # Fused LSTM weights: [x; h] @ W + b -> gates (i, f, g, o).
    ParamSpec("lstm_w", (EMBED + HIDDEN, 4 * HIDDEN)),
    ParamSpec("lstm_b", (4 * HIDDEN,)),
    ParamSpec("head_w", (HIDDEN, VOCAB_SIZE)),
    ParamSpec("head_b", (VOCAB_SIZE,)),
)
PARAM_SIZE = total_size(SPECS)
INIT_SCALES = {"embed": 0.1, "lstm_w": 0.08, "head_w": 0.08}
X_SHAPE = (SEQ_LEN,)
X_DTYPE = "i32"


def _cell(
    p: Dict[str, jnp.ndarray],
    carry: Tuple[jnp.ndarray, jnp.ndarray],
    xt: jnp.ndarray,
) -> Tuple[Tuple[jnp.ndarray, jnp.ndarray], jnp.ndarray]:
    h, c = carry
    z = jnp.concatenate([xt, h], axis=-1) @ p["lstm_w"] + p["lstm_b"]
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), h


def apply(flat_params: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """x [B, SEQ_LEN] i32 token ids -> logits [B, SEQ_LEN, VOCAB_SIZE].

    Position t predicts the *next* character; the data layer supplies the
    shifted targets y [B, SEQ_LEN].
    """
    p: Dict[str, jnp.ndarray] = unflatten(flat_params, SPECS)
    emb = p["embed"][x]  # [B, S, E]
    batch = emb.shape[0]
    h0 = jnp.zeros((batch, HIDDEN), jnp.float32)
    c0 = jnp.zeros((batch, HIDDEN), jnp.float32)

    def step(carry, xt):
        return _cell(p, carry, xt)

    # scan over time: emb -> [S, B, E]
    _, hs = jax.lax.scan(step, (h0, c0), jnp.swapaxes(emb, 0, 1))
    hs = jnp.swapaxes(hs, 0, 1)  # [B, S, H]
    return hs @ p["head_w"] + p["head_b"]
