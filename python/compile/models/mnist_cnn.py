"""MNIST-benchmark model: three-layer CNN for 10-way digit classification.

Mirrors the paper's "three-layer CNN" (section 6.1, dataset 1): two small
convolutions with 2x2 max-pooling, one dense classifier head. Kept compact
(~9k parameters) so the AOT-compiled HLO executes fast on the CPU PJRT
client while remaining a genuine convolutional workload.

Input crosses the HLO boundary as a flat f32[B, 784] row (the rust data
layer stores images as flat vectors); the model reshapes to NHWC inside.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .base import ParamSpec, total_size, unflatten

NAME = "mnist"
IMG = 28
NUM_CLASSES = 10
C1, C2 = 8, 16

SPECS = (
    ParamSpec("conv1", (3, 3, 1, C1)),
    ParamSpec("bias1", (C1,)),
    ParamSpec("conv2", (3, 3, C1, C2)),
    ParamSpec("bias2", (C2,)),
    ParamSpec("dense", (7 * 7 * C2, NUM_CLASSES)),
    ParamSpec("bias3", (NUM_CLASSES,)),
)
PARAM_SIZE = total_size(SPECS)
INIT_SCALES = {"conv1": 0.3, "conv2": 0.1, "dense": 0.03}
X_SHAPE = (IMG * IMG,)
X_DTYPE = "f32"

_DN = ("NHWC", "HWIO", "NHWC")


def _conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME", dimension_numbers=_DN
    )


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def apply(flat_params: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """x [B, 784] -> logits [B, 10]."""
    p: Dict[str, jnp.ndarray] = unflatten(flat_params, SPECS)
    h = x.reshape(-1, IMG, IMG, 1)
    h = jax.nn.relu(_conv(h, p["conv1"]) + p["bias1"])
    h = _maxpool2(h)  # 14x14xC1
    h = jax.nn.relu(_conv(h, p["conv2"]) + p["bias2"])
    h = _maxpool2(h)  # 7x7xC2
    h = h.reshape(h.shape[0], -1)
    return h @ p["dense"] + p["bias3"]
