"""AOT compile path: lower every (model × function) pair plus the L1
pairwise-distance kernel to HLO *text* artifacts the rust runtime loads.

Why text, not `lowered.compile().serialize()` / serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids, which the xla crate's
bundled xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The HLO text
parser reassigns ids on load, so text round-trips cleanly (see
/opt/xla-example/README.md).

Also emits ``artifacts/manifest.json`` — the single source of truth for
shapes, dtypes, parameter sizes, initial parameter vectors and the char
vocabulary that the rust side consumes. Python never runs after this.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import DEFAULT_C, DEFAULT_T, pairwise_tile
from .model import ALL_MODELS, FEATURE_DIM, FN_FACTORIES, example_args
from .models.base import init_flat
from .vocab import VOCAB

# Paper Table 3: batch size 8 for local SGD. F is the batch used for
# feature extraction / evaluation (throughput-oriented, any size works).
TRAIN_BATCH = 8
FEAT_BATCH = 64
INIT_SEED = 17


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn: Callable, args: Tuple) -> str:
    return to_hlo_text(jax.jit(fn).lower(*args))


def _write(path: str, text: str) -> str:
    with open(path, "w") as f:
        f.write(text)
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def build_all(out_dir: str, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {
        "format": "hlo-text",
        "train_batch": TRAIN_BATCH,
        "feat_batch": FEAT_BATCH,
        "feature_dim": FEATURE_DIM,
        "pairwise": {"tile": DEFAULT_T, "dim": DEFAULT_C},
        "vocab": VOCAB,
        "models": {},
        "artifacts": {},
    }

    for name, model in ALL_MODELS.items():
        entry = {
            "param_size": model.PARAM_SIZE,
            "num_classes": model.NUM_CLASSES,
            "x_shape": list(model.X_SHAPE),
            "x_dtype": model.X_DTYPE,
            "seq_len": getattr(model, "SEQ_LEN", 0),
            "functions": {},
        }
        for fn_name, factory in FN_FACTORIES.items():
            batch = TRAIN_BATCH if fn_name == "train" else FEAT_BATCH
            fname = f"{name}_{fn_name}.hlo.txt"
            path = os.path.join(out_dir, fname)
            text = lower_fn(factory(model), example_args(model, fn_name, batch))
            digest = _write(path, text)
            entry["functions"][fn_name] = {"file": fname, "batch": batch}
            manifest["artifacts"][fname] = digest
            if verbose:
                print(f"  {fname:24s} {len(text):>9d} chars  sha={digest}")
        # Deterministic initial parameter vector, shipped in the manifest so
        # rust and python agree bit-for-bit on w_0.
        init = init_flat(model.SPECS, jax.random.PRNGKey(INIT_SEED), model.INIT_SCALES)
        entry["init_params"] = [float(v) for v in jnp.asarray(init)]
        manifest["models"][name] = entry

    # L1 Pallas kernel: one T x T distance tile (rust tiles the full matrix).
    tile_fn = pairwise_tile(DEFAULT_T, DEFAULT_C)
    spec = jax.ShapeDtypeStruct((DEFAULT_T, DEFAULT_C), jnp.float32)
    fname = "pairwise_dist.hlo.txt"
    text = lower_fn(tile_fn, (spec, spec))
    digest = _write(os.path.join(out_dir, fname), text)
    manifest["artifacts"][fname] = digest
    if verbose:
        print(f"  {fname:24s} {len(text):>9d} chars  sha={digest}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if verbose:
        print(f"  manifest.json            ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description="Lower FedCore artifacts to HLO text")
    ap.add_argument("--out", default="../artifacts", help="output dir (or model.hlo.txt path)")
    args = ap.parse_args()
    out = args.out
    # Makefile passes a file path ending in .hlo.txt; treat its dir as out_dir.
    out_dir = os.path.dirname(out) if out.endswith(".txt") else out
    build_all(out_dir or ".")
    # Sentinel for make's dependency tracking.
    if out.endswith(".txt") and not os.path.exists(out):
        with open(out, "w") as f:
            f.write("# sentinel; see manifest.json\n")


if __name__ == "__main__":
    main()
